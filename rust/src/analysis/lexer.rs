//! Minimal lexical pass for the audit engine: split Rust source into
//! per-line *code* and *comment* channels.
//!
//! The engine scans tokens in the code channel only, so forbidden names
//! inside string literals, doc comments, or `//` prose never false-fire
//! (the audit's own rule catalogue and the fixture tests would otherwise
//! flag themselves). Comment text is kept separately because that is
//! where `audit:allow(...)` suppression directives live.
//!
//! This is deliberately *not* a full Rust lexer: it understands exactly
//! the constructs that matter for channel separation — line comments,
//! nested block comments, string / raw-string / byte-string / char
//! literals, and the `'a` lifetime-vs-char-literal ambiguity — and blanks
//! literal contents out of the code channel while preserving the line
//! structure of the file.

/// One source line, split into lexical channels.
#[derive(Debug, Clone, Default)]
pub struct LineView {
    /// Code with comments removed and string/char-literal contents
    /// blanked.
    pub code: String,
    /// Concatenated comment text appearing on the line.
    pub comment: String,
}

enum State {
    Normal,
    LineComment,
    /// Nested depth of `/* ... */`.
    BlockComment(usize),
    Str,
    /// Raw string, closing delimiter is `"` followed by this many `#`s.
    RawStr(usize),
    CharLit,
}

/// Lex `text` into per-line [`LineView`]s. Never fails: unterminated
/// literals or comments simply run to end-of-file in their channel.
pub fn lex(text: &str) -> Vec<LineView> {
    let cs: Vec<char> = text.chars().collect();
    let n = cs.len();
    let mut lines: Vec<LineView> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Normal;
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            lines.push(LineView {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if c == '/' && i + 1 < n && cs[i + 1] == '/' {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && i + 1 < n && cs[i + 1] == '*' {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    code.push(' ');
                    i += 1;
                } else if c == 'r' || c == 'b' {
                    // r"..." / r#"..."# / br"..." raw strings. A bare
                    // `r` or `b` identifier char falls through below.
                    let mut j = i;
                    if cs[j] == 'b' && j + 1 < n && cs[j + 1] == 'r' {
                        j += 1;
                    }
                    let mut opened = false;
                    if cs[j] == 'r' {
                        let mut k = j + 1;
                        let mut hashes = 0usize;
                        while k < n && cs[k] == '#' {
                            hashes += 1;
                            k += 1;
                        }
                        if k < n && cs[k] == '"' {
                            state = State::RawStr(hashes);
                            for _ in i..=k {
                                code.push(' ');
                            }
                            i = k + 1;
                            opened = true;
                        }
                    }
                    if !opened {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    if i + 1 < n && cs[i + 1] == '\\' {
                        // '\n' style escaped char literal.
                        state = State::CharLit;
                        code.push(' ');
                        i += 1;
                    } else if i + 2 < n && cs[i + 2] == '\'' {
                        // Plain 'x' char literal.
                        code.push_str("   ");
                        i += 3;
                    } else {
                        // Lifetime tick ('a in a generic position).
                        code.push(' ');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '/' && i + 1 < n && cs[i + 1] == '*' {
                    state = State::BlockComment(depth + 1);
                    comment.push(' ');
                    i += 2;
                } else if c == '*' && i + 1 < n && cs[i + 1] == '/' {
                    state = if depth == 1 {
                        State::Normal
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '"' {
                        state = State::Normal;
                    }
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut k = i + 1;
                    let mut got = 0usize;
                    while k < n && cs[k] == '#' && got < hashes {
                        got += 1;
                        k += 1;
                    }
                    if got == hashes {
                        state = State::Normal;
                        i = k;
                        continue;
                    }
                }
                i += 1;
            }
            State::CharLit => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '\'' {
                        state = State::Normal;
                    }
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(LineView { code, comment });
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_split_out() {
        let v = lex("let x = 1; // trailing note\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].code.trim_end(), "let x = 1;");
        assert_eq!(v[0].comment.trim(), "trailing note");
    }

    #[test]
    fn string_contents_are_blanked() {
        let v = lex("let s = \"HashMap .unwrap() panic!\"; f(s);\n");
        assert!(!v[0].code.contains("HashMap"));
        assert!(!v[0].code.contains("unwrap"));
        assert!(v[0].code.contains("f(s);"));
    }

    #[test]
    fn raw_string_contents_are_blanked() {
        let v = lex("let s = r#\"Instant \"quoted\" body\"#; g();\n");
        assert!(!v[0].code.contains("Instant"));
        assert!(v[0].code.contains("g();"));
    }

    #[test]
    fn nested_block_comment() {
        let v = lex("a(); /* outer /* inner */ still comment */ b();\n");
        assert!(v[0].code.contains("a();"));
        assert!(v[0].code.contains("b();"));
        assert!(!v[0].code.contains("still"));
        assert!(v[0].comment.contains("inner"));
    }

    #[test]
    fn multi_line_string_spans_lines() {
        let v = lex("let s = \"first HashMap\nsecond Instant\"; h();\n");
        assert_eq!(v.len(), 2);
        assert!(!v[0].code.contains("HashMap"));
        assert!(!v[1].code.contains("Instant"));
        assert!(v[1].code.contains("h();"));
    }

    #[test]
    fn char_literal_and_lifetime() {
        let v = lex("fn f<'a>(x: &'a str) { let c = '\"'; let d = 'y'; }\n");
        // The quote character literal must not open a string state.
        assert!(v[0].code.contains("let d ="));
        let v = lex("let q = 'Q'; q2();\n");
        assert!(v[0].code.contains("q2();"));
        assert!(!v[0].code.contains('Q'));
    }

    #[test]
    fn escaped_quote_inside_string() {
        let v = lex("let s = \"a\\\"b Instant c\"; tail();\n");
        assert!(!v[0].code.contains("Instant"));
        assert!(v[0].code.contains("tail();"));
    }

    #[test]
    fn line_comment_ends_at_newline() {
        let v = lex("// only a comment\ncode();\n");
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].code.trim(), "");
        assert!(v[1].code.contains("code();"));
    }
}
