//! Scenario specifications: *what* varies round to round, and *when* the
//! resource optimizer re-solves.
//!
//! A [`ScenarioSpec`] is pure data — each enabled dynamic is expanded into
//! a deterministic per-round sequence by [`super::engine`]. A
//! [`ReoptPolicy`] decides at which rounds BCD re-runs; policies are
//! parsed from the `"never" | "every:<k>" | "regress:<x>" | "oracle"`
//! strings used by the CLI and the `[scenario]` config section.

use crate::config::ScenarioSettings;
use crate::error::{Error, Result};

/// Per-round LoS↔NLoS Markov flips. Each round, client `i` at distance
/// `d_i` flips LoS→NLoS with probability
/// `flip_prob · (1 − P_LoS(d_i))` and NLoS→LoS with probability
/// `flip_prob · P_LoS(d_i)`, so the chain's stationary distribution is the
/// 3GPP distance-dependent LoS probability the deployment was drawn from —
/// far clients spend more rounds blocked, near clients barely flip.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LosFlipSpec {
    /// Flip-rate scale in (0, 1]: expected time between state changes is
    /// roughly `1 / flip_prob` rounds.
    pub flip_prob: f64,
}

/// Per-round multiplicative client-compute jitter: round `r` runs client
/// `i` at `f_i · (1 + U(−amplitude, +amplitude))`, memoryless around the
/// deployment's base capability (thermal throttling / background load).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeJitterSpec {
    /// Fractional amplitude in [0, 1).
    pub amplitude: f64,
}

/// Client dropout / re-arrival churn over a fixed roster: an active client
/// drops with `drop_prob` per round, a dropped client re-joins with
/// `rejoin_prob`; the active set never shrinks below `min_active`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnSpec {
    pub drop_prob: f64,
    pub rejoin_prob: f64,
    pub min_active: usize,
}

/// Multi-round network dynamics, expanded by [`super::Scenario`] into a
/// per-round sequence of deployments + channel realizations.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Number of rounds the scenario spans.
    pub rounds: usize,
    /// Block-fading redraw period: a fresh shadow-fading realization every
    /// `k` rounds (`Some(1)` = every round, the Fig. 13 setting). `None`
    /// holds the channel at the deterministic average gains.
    pub redraw_period: Option<usize>,
    pub los_flip: Option<LosFlipSpec>,
    pub compute_jitter: Option<ComputeJitterSpec>,
    pub churn: Option<ChurnSpec>,
}

impl ScenarioSpec {
    /// Fully static scenario: average gains, fixed deployment — the
    /// "ideal static channel" benchmark of Fig. 13.
    pub fn static_channel(rounds: usize) -> ScenarioSpec {
        ScenarioSpec {
            rounds,
            redraw_period: None,
            los_flip: None,
            compute_jitter: None,
            churn: None,
        }
    }

    /// Pure per-round shadow-fading redraws (the pre-scenario Fig. 13
    /// loop): no LoS flips, jitter, or churn, so the expansion consumes
    /// the caller's RNG stream exactly as `n` sequential
    /// `ChannelRealization::sample` calls.
    pub fn fading(rounds: usize) -> ScenarioSpec {
        ScenarioSpec { redraw_period: Some(1), ..Self::static_channel(rounds) }
    }

    /// Block-fading variant: redraw every `period` rounds.
    pub fn block_fading(rounds: usize, period: usize) -> ScenarioSpec {
        ScenarioSpec {
            redraw_period: Some(period.max(1)),
            ..Self::static_channel(rounds)
        }
    }

    /// Typed spec from the plain `[scenario]` config section (the section
    /// validates ranges; this adds the structural checks).
    pub fn from_settings(s: &ScenarioSettings, rounds: usize)
        -> Result<ScenarioSpec> {
        s.validate()?;
        let spec = ScenarioSpec {
            rounds,
            redraw_period: if s.redraw_period == 0 {
                None
            } else {
                Some(s.redraw_period)
            },
            los_flip: (s.los_flip_prob > 0.0)
                .then_some(LosFlipSpec { flip_prob: s.los_flip_prob }),
            compute_jitter: (s.compute_jitter > 0.0)
                .then_some(ComputeJitterSpec { amplitude: s.compute_jitter }),
            churn: (s.drop_prob > 0.0 || s.rejoin_prob > 0.0).then_some(
                ChurnSpec {
                    drop_prob: s.drop_prob,
                    rejoin_prob: s.rejoin_prob,
                    min_active: s.min_active,
                },
            ),
        };
        spec.validate(usize::MAX)?;
        Ok(spec)
    }

    /// Structural validation against a roster of `n_clients`.
    pub fn validate(&self, n_clients: usize) -> Result<()> {
        if self.rounds == 0 {
            return Err(Error::Config("scenario rounds must be > 0".into()));
        }
        if self.redraw_period == Some(0) {
            return Err(Error::Config(
                "scenario redraw period must be > 0 (use None for a \
                 static channel)"
                    .into(),
            ));
        }
        if let Some(f) = &self.los_flip {
            if !(0.0..=1.0).contains(&f.flip_prob) {
                return Err(Error::Config(format!(
                    "los flip_prob {} out of [0,1]",
                    f.flip_prob
                )));
            }
        }
        if let Some(j) = &self.compute_jitter {
            if !(0.0..1.0).contains(&j.amplitude) {
                return Err(Error::Config(format!(
                    "compute jitter amplitude {} out of [0,1)",
                    j.amplitude
                )));
            }
        }
        if let Some(c) = &self.churn {
            if !(0.0..=1.0).contains(&c.drop_prob)
                || !(0.0..=1.0).contains(&c.rejoin_prob)
            {
                return Err(Error::Config(
                    "churn probabilities out of [0,1]".into(),
                ));
            }
            if c.min_active == 0 || c.min_active > n_clients {
                return Err(Error::Config(format!(
                    "churn min_active {} out of 1..={n_clients}",
                    c.min_active
                )));
            }
        }
        Ok(())
    }
}

/// When the BCD optimizer re-solves along a scenario.
///
/// A membership change (churn) always forces a re-solve regardless of the
/// policy — a decision's subchannel→client map is meaningless for a
/// different client set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReoptPolicy {
    /// Optimize once on the round-0 average gains, never again — the
    /// paper's "the cut layer decision, once determined, could last for a
    /// long period".
    Never,
    /// Re-solve every `k` rounds on that round's realized gains
    /// (`EveryK(1)` is the Fig. 13 oracle).
    EveryK(usize),
    /// Re-solve (on current realized gains) whenever the round latency
    /// exceeds `threshold ×` the latency observed at the last solve.
    OnRegression(f64),
}

impl ReoptPolicy {
    /// Parse the CLI / config string form.
    pub fn parse(s: &str) -> Result<ReoptPolicy> {
        match s {
            "never" => return Ok(ReoptPolicy::Never),
            "oracle" => return Ok(ReoptPolicy::EveryK(1)),
            _ => {}
        }
        if let Some(k) = s.strip_prefix("every:") {
            let k: usize = k.parse().map_err(|_| {
                Error::Config(format!("reopt every:<k>: bad k in '{s}'"))
            })?;
            if k == 0 {
                return Err(Error::Config("reopt every:0 is invalid".into()));
            }
            return Ok(ReoptPolicy::EveryK(k));
        }
        if let Some(x) = s.strip_prefix("regress:") {
            let x: f64 = x.parse().map_err(|_| {
                Error::Config(format!("reopt regress:<x>: bad x in '{s}'"))
            })?;
            if !x.is_finite() || x < 1.0 {
                return Err(Error::Config(format!(
                    "reopt regress threshold {x} must be >= 1"
                )));
            }
            return Ok(ReoptPolicy::OnRegression(x));
        }
        Err(Error::Config(format!(
            "unknown reopt policy '{s}' (never | every:<k> | regress:<x> | \
             oracle)"
        )))
    }

    /// Short display name for tables.
    pub fn name(&self) -> String {
        match self {
            ReoptPolicy::Never => "never".into(),
            ReoptPolicy::EveryK(1) => "oracle".into(),
            ReoptPolicy::EveryK(k) => format!("every:{k}"),
            ReoptPolicy::OnRegression(x) => format!("regress:{x}"),
        }
    }
}

/// Driver-facing bundle: the spec plus the re-optimization policy the
/// training run tracks (`TrainerOptions::dynamic_channel`).
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicChannel {
    pub spec: ScenarioSpec,
    pub policy: ReoptPolicy,
}

impl DynamicChannel {
    /// From the `[scenario]` config section for a run of `rounds` rounds.
    pub fn from_settings(s: &ScenarioSettings, rounds: usize)
        -> Result<DynamicChannel> {
        Ok(DynamicChannel {
            spec: ScenarioSpec::from_settings(s, rounds)?,
            policy: ReoptPolicy::parse(&s.reopt)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(ReoptPolicy::parse("never").unwrap(), ReoptPolicy::Never);
        assert_eq!(
            ReoptPolicy::parse("oracle").unwrap(),
            ReoptPolicy::EveryK(1)
        );
        assert_eq!(
            ReoptPolicy::parse("every:5").unwrap(),
            ReoptPolicy::EveryK(5)
        );
        assert_eq!(
            ReoptPolicy::parse("regress:1.2").unwrap(),
            ReoptPolicy::OnRegression(1.2)
        );
        assert!(ReoptPolicy::parse("every:0").is_err());
        assert!(ReoptPolicy::parse("regress:0.5").is_err());
        assert!(ReoptPolicy::parse("sometimes").is_err());
        assert_eq!(ReoptPolicy::EveryK(1).name(), "oracle");
        assert_eq!(ReoptPolicy::EveryK(4).name(), "every:4");
    }

    #[test]
    fn spec_validation() {
        assert!(ScenarioSpec::static_channel(10).validate(5).is_ok());
        assert!(ScenarioSpec::fading(10).validate(5).is_ok());
        assert!(ScenarioSpec::static_channel(0).validate(5).is_err());
        let mut s = ScenarioSpec::fading(10);
        s.redraw_period = Some(0);
        assert!(s.validate(5).is_err());
        let mut s = ScenarioSpec::fading(10);
        s.churn = Some(ChurnSpec {
            drop_prob: 0.1,
            rejoin_prob: 0.5,
            min_active: 6,
        });
        assert!(s.validate(5).is_err(), "min_active above roster");
        assert!(s.validate(6).is_ok());
    }

    #[test]
    fn spec_from_settings() {
        let mut st = crate::config::ScenarioSettings::default();
        st.redraw_period = 0;
        let spec = ScenarioSpec::from_settings(&st, 8).unwrap();
        assert_eq!(spec.redraw_period, None);
        assert!(spec.los_flip.is_none());
        st.redraw_period = 3;
        st.los_flip_prob = 0.2;
        st.compute_jitter = 0.1;
        st.drop_prob = 0.05;
        let spec = ScenarioSpec::from_settings(&st, 8).unwrap();
        assert_eq!(spec.redraw_period, Some(3));
        assert_eq!(spec.los_flip.unwrap().flip_prob, 0.2);
        assert_eq!(spec.compute_jitter.unwrap().amplitude, 0.1);
        assert_eq!(spec.churn.unwrap().drop_prob, 0.05);
        let dc = DynamicChannel::from_settings(&st, 8).unwrap();
        assert_eq!(dc.policy, ReoptPolicy::Never);
    }
}
