//! Multi-round network dynamics as a first-class abstraction.
//!
//! The paper optimizes resources once, on average channel gains, and holds
//! the decision — its Fig. 13 robustness claim is that this stays
//! near-oracle under per-round channel variation. Before this module the
//! repo could only ask that one question, through an ad-hoc loop inside
//! `fig13`; the training driver froze a single averaged channel for every
//! round. Here the dynamics themselves become data:
//!
//! - [`ScenarioSpec`] — *what varies*: block-fading redraw period,
//!   distance-dependent LoS Markov flips, client compute jitter, and
//!   client dropout/arrival churn ([`spec`]);
//! - [`Scenario`] — a spec expanded from a seed into a deterministic
//!   per-round sequence of deployments + channel realizations
//!   ([`engine`]);
//! - [`ReoptPolicy`] — *when the optimizer re-solves*: `Never`,
//!   `EveryK(k)`, or `OnRegression(threshold)`, evaluated on the
//!   `optim::eval` fast path with solve blocks fanned across cores
//!   ([`run`]);
//! - [`ScenarioCell`] — grid cells for parallel sweeps over
//!   spec × policy × seed ([`sweep`]), feeding Fig. 13 / Fig. 13b;
//! - [`FaultSpec`] — *what breaks*: scheduled + probabilistic client
//!   crashes, delayed uplinks, corrupted payloads, and server aborts,
//!   expanded from the run seed into a [`FaultPlan`] the coordinator
//!   executes with quorum/retry/deadline resilience ([`faults`]).
//!
//! Everything is bit-identical for any thread count (`EPSL_THREADS=1`
//! forces serial), and a pure-fading spec consumes the RNG stream exactly
//! as the pre-scenario Fig. 13 loop did, so the refactored figure
//! reproduces its numbers. Knobs are documented in EXPERIMENTS.md.

pub mod engine;
pub mod faults;
pub mod run;
pub mod spec;
pub mod sweep;

pub use engine::{Scenario, ScenarioRound};
pub use faults::{FaultEvent, FaultKind, FaultPlan, FaultSpec, RoundFaults};
pub use run::{
    pair_latencies, run_policy, run_policy_with_rates, PairedStats,
    RoundOutcome, RoundRates, RunOptions, ScenarioOutcome,
};
pub use spec::{
    ChurnSpec, ComputeJitterSpec, DynamicChannel, LosFlipSpec, ReoptPolicy,
    ScenarioSpec,
};
pub use sweep::{
    eval_scenario_cell, run_scenario_cells, ScenarioCell, ScenarioSummary,
};
