//! Deterministic fault injection: *what breaks*, round by round.
//!
//! A [`FaultSpec`] sits beside [`super::ScenarioSpec`] and describes the
//! failure process of a training run — scheduled events (client crash,
//! delayed uplink, corrupted activation payload, server abort) plus
//! probabilistic per-round knobs — together with the resilience policy
//! the coordinator applies (quorum floor, bounded retry with backoff,
//! straggler deadline factor). [`FaultSpec::expand`] turns the spec into
//! a concrete [`FaultPlan`] from the run seed, following the scenario
//! engine's stream discipline: one base fork when any probabilistic knob
//! is enabled, private per-feature sub-streams derived from clones of it
//! (so crash draws do not depend on whether delays are also enabled),
//! and **zero** RNG consumption for a purely scheduled spec — a run with
//! only scheduled faults keeps the exact batch-sampling stream of a
//! fault-free run.

use crate::config::FaultSettings;
use crate::error::{Error, Result};
use crate::util::rng::{streams, Rng};

/// One kind of injected failure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Client `i` crashes for the round: it samples no batch, sends no
    /// smashed data, and is dropped from the round's cohort.
    ClientCrash(usize),
    /// Client `i`'s smashed-data uplink arrives `delay_s` seconds late.
    /// Past the straggler deadline the client is dropped; within it, the
    /// overshoot is accounted as recovery latency.
    DelayedUplink { client: usize, delay_s: f64 },
    /// Client `i`'s activation payload arrives corrupted; the coordinator
    /// detects it and retries (bounded, with backoff).
    CorruptPayload(usize),
    /// The server aborts mid-round before committing its update; the
    /// fused step is retried and nothing is committed until it succeeds.
    ServerAbort,
}

/// A scheduled fault: `kind` fires at training round `round`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub round: usize,
    pub kind: FaultKind,
}

/// Fault process + resilience policy for one training run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Explicitly scheduled events (deterministic, seed-independent).
    pub events: Vec<FaultEvent>,
    /// Per-client per-round crash probability.
    pub crash_prob: f64,
    /// Per-client per-round delayed-uplink probability.
    pub delay_prob: f64,
    /// Delay seconds applied by probabilistic `delay_prob` events.
    pub delay_s: f64,
    /// Per-client per-round corrupted-payload probability.
    pub corrupt_prob: f64,
    /// Per-round server-abort probability.
    pub abort_prob: f64,
    /// Minimum surviving cohort a round may commit with; below it the
    /// run fails with [`Error::Quorum`] naming the round.
    pub quorum: usize,
    /// Bounded retries for transient faults (corrupt payload / server
    /// abort). With 0 retries a corrupt client is dropped instead.
    pub max_retries: usize,
    /// Base backoff seconds charged per retry (linear in the attempt).
    pub retry_backoff_s: f64,
    /// Straggler deadline as a multiple of the round's nominal slowest
    /// uplink arrival (must be >= 1; the deadline can only bite clients
    /// with injected delay).
    pub deadline_factor: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            events: Vec::new(),
            crash_prob: 0.0,
            delay_prob: 0.0,
            delay_s: 0.5,
            corrupt_prob: 0.0,
            abort_prob: 0.0,
            quorum: 1,
            max_retries: 2,
            retry_backoff_s: 0.05,
            deadline_factor: 1.5,
        }
    }
}

impl FaultSpec {
    /// Parse the compact CLI event list: comma-separated
    /// `crash@<round>:<client>`, `delay@<round>:<client>:<seconds>`,
    /// `corrupt@<round>:<client>`, `abort@<round>`. Empty input is an
    /// empty schedule.
    pub fn parse_events(s: &str) -> Result<Vec<FaultEvent>> {
        let mut events = Vec::new();
        for raw in s.split(',') {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            let (kind, rest) = item.split_once('@').ok_or_else(|| {
                Error::Config(format!(
                    "fault event '{item}' missing '@' (expected e.g. \
                     crash@3:1, delay@4:0:2.5, corrupt@5:2, abort@6)"
                ))
            })?;
            let fields: Vec<&str> = rest.split(':').collect();
            let num = |f: &str, what: &str| -> Result<usize> {
                f.parse().map_err(|_| {
                    Error::Config(format!(
                        "fault event '{item}': bad {what} '{f}'"
                    ))
                })
            };
            let kind = match (kind, fields.as_slice()) {
                ("crash", [r, c]) => FaultEvent {
                    round: num(r, "round")?,
                    kind: FaultKind::ClientCrash(num(c, "client")?),
                },
                ("delay", [r, c, d]) => FaultEvent {
                    round: num(r, "round")?,
                    kind: FaultKind::DelayedUplink {
                        client: num(c, "client")?,
                        delay_s: d.parse().map_err(|_| {
                            Error::Config(format!(
                                "fault event '{item}': bad delay '{d}'"
                            ))
                        })?,
                    },
                },
                ("corrupt", [r, c]) => FaultEvent {
                    round: num(r, "round")?,
                    kind: FaultKind::CorruptPayload(num(c, "client")?),
                },
                ("abort", [r]) => FaultEvent {
                    round: num(r, "round")?,
                    kind: FaultKind::ServerAbort,
                },
                _ => {
                    return Err(Error::Config(format!(
                        "fault event '{item}' unrecognized (crash@r:c | \
                         delay@r:c:s | corrupt@r:c | abort@r)"
                    )))
                }
            };
            events.push(kind);
        }
        Ok(events)
    }

    /// Typed spec from the plain `[faults]` config section.
    pub fn from_settings(s: &FaultSettings) -> Result<FaultSpec> {
        s.validate()?;
        Ok(FaultSpec {
            events: Self::parse_events(&s.events)?,
            crash_prob: s.crash_prob,
            delay_prob: s.delay_prob,
            delay_s: s.delay_s,
            corrupt_prob: s.corrupt_prob,
            abort_prob: s.abort_prob,
            quorum: s.quorum,
            max_retries: s.max_retries,
            retry_backoff_s: s.retry_backoff_s,
            deadline_factor: s.deadline_factor,
        })
    }

    /// Structural validation against a run of `rounds` rounds over
    /// `n_clients` clients.
    pub fn validate(&self, n_clients: usize, rounds: usize) -> Result<()> {
        for (name, p) in [
            ("crash_prob", self.crash_prob),
            ("delay_prob", self.delay_prob),
            ("corrupt_prob", self.corrupt_prob),
            ("abort_prob", self.abort_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "faults.{name}={p} out of [0,1]"
                )));
            }
        }
        for (name, v) in [
            ("delay_s", self.delay_s),
            ("retry_backoff_s", self.retry_backoff_s),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(Error::Config(format!(
                    "faults.{name}={v} must be finite and >= 0"
                )));
            }
        }
        if !self.deadline_factor.is_finite() || self.deadline_factor < 1.0 {
            return Err(Error::Config(format!(
                "faults.deadline_factor={} must be >= 1 (the deadline is \
                 a multiple of the nominal slowest arrival)",
                self.deadline_factor
            )));
        }
        if self.quorum == 0 || self.quorum > n_clients {
            return Err(Error::Config(format!(
                "faults.quorum {} out of 1..={n_clients}",
                self.quorum
            )));
        }
        for ev in &self.events {
            if ev.round >= rounds {
                return Err(Error::Config(format!(
                    "fault event at round {} beyond the run's {rounds} \
                     round(s)",
                    ev.round
                )));
            }
            let client = match ev.kind {
                FaultKind::ClientCrash(c)
                | FaultKind::CorruptPayload(c) => Some(c),
                FaultKind::DelayedUplink { client, delay_s } => {
                    if !delay_s.is_finite() || delay_s < 0.0 {
                        return Err(Error::Config(format!(
                            "fault delay {delay_s} at round {} must be \
                             finite and >= 0",
                            ev.round
                        )));
                    }
                    Some(client)
                }
                FaultKind::ServerAbort => None,
            };
            if let Some(c) = client {
                if c >= n_clients {
                    return Err(Error::Config(format!(
                        "fault event targets client {c} but the run has \
                         {n_clients} client(s)"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Does the spec draw any randomness at expansion time?
    pub fn has_random(&self) -> bool {
        self.crash_prob > 0.0
            || self.delay_prob > 0.0
            || self.corrupt_prob > 0.0
            || self.abort_prob > 0.0
    }

    /// Expand into a per-round plan. Scheduled events consume no RNG; the
    /// probabilistic knobs draw from private sub-streams forked off one
    /// base (scenario-engine discipline), so each feature's draws are
    /// invariant to which other features are enabled.
    pub fn expand(&self, rounds: usize, n_clients: usize, rng: &mut Rng)
        -> Result<FaultPlan> {
        self.validate(n_clients, rounds)?;
        let mut plan = vec![RoundFaults::default(); rounds];
        for ev in &self.events {
            let rf = &mut plan[ev.round];
            match ev.kind {
                FaultKind::ClientCrash(c) => rf.crashed.push(c),
                FaultKind::DelayedUplink { client, delay_s } => {
                    rf.delays.push((client, delay_s))
                }
                FaultKind::CorruptPayload(c) => rf.corrupt.push(c),
                FaultKind::ServerAbort => rf.server_abort = true,
            }
        }
        if self.has_random() {
            let mut base = rng.fork(streams::FAULT_PLAN);
            let sub = |base: &Rng, tag: u64| {
                let mut b = base.clone();
                b.fork(tag)
            };
            if self.crash_prob > 0.0 {
                let mut r = sub(&base, streams::FAULT_CRASH);
                for rf in plan.iter_mut() {
                    for c in 0..n_clients {
                        if r.chance(self.crash_prob) {
                            rf.crashed.push(c);
                        }
                    }
                }
            }
            if self.delay_prob > 0.0 {
                let mut r = sub(&base, streams::FAULT_DELAY);
                for rf in plan.iter_mut() {
                    for c in 0..n_clients {
                        if r.chance(self.delay_prob) {
                            rf.delays.push((c, self.delay_s));
                        }
                    }
                }
            }
            if self.corrupt_prob > 0.0 {
                let mut r = sub(&base, streams::FAULT_CORRUPT);
                for rf in plan.iter_mut() {
                    for c in 0..n_clients {
                        if r.chance(self.corrupt_prob) {
                            rf.corrupt.push(c);
                        }
                    }
                }
            }
            if self.abort_prob > 0.0 {
                let mut r = sub(&base, streams::FAULT_ABORT);
                for rf in plan.iter_mut() {
                    if r.chance(self.abort_prob) {
                        rf.server_abort = true;
                    }
                }
            }
            // `base` itself is never drawn from; forking it above is what
            // decorrelates the sub-streams from the parent.
            let _ = &mut base;
        }
        for rf in plan.iter_mut() {
            rf.normalize();
        }
        Ok(FaultPlan { rounds: plan })
    }
}

/// One round's injected faults, normalized (sorted, deduplicated, crash
/// dominating the other per-client faults).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundFaults {
    /// Clients that crash this round (sorted, unique).
    pub crashed: Vec<usize>,
    /// (client, extra uplink seconds) — sorted by client, one entry per
    /// client, crashed clients excluded.
    pub delays: Vec<(usize, f64)>,
    /// Clients whose payload arrives corrupted (sorted, unique, crashed
    /// clients excluded).
    pub corrupt: Vec<usize>,
    /// Server aborts mid-round before committing.
    pub server_abort: bool,
}

impl RoundFaults {
    /// Number of injected fault events this round.
    pub fn n_injected(&self) -> usize {
        self.crashed.len()
            + self.delays.len()
            + self.corrupt.len()
            + usize::from(self.server_abort)
    }

    pub fn is_quiet(&self) -> bool {
        self.n_injected() == 0
    }

    fn normalize(&mut self) {
        self.crashed.sort_unstable();
        self.crashed.dedup();
        // A crash dominates: a crashed client has no payload to delay or
        // corrupt.
        self.delays.sort_by(|a, b| a.0.cmp(&b.0));
        self.delays.dedup_by_key(|d| d.0);
        self.delays.retain(|(c, _)| !self.crashed.contains(c));
        self.corrupt.sort_unstable();
        self.corrupt.dedup();
        self.corrupt.retain(|c| !self.crashed.contains(c));
    }
}

/// A fully expanded fault plan: one [`RoundFaults`] per training round.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub rounds: Vec<RoundFaults>,
}

impl FaultPlan {
    /// A plan that injects nothing.
    pub fn quiet(rounds: usize) -> FaultPlan {
        FaultPlan { rounds: vec![RoundFaults::default(); rounds] }
    }

    /// This round's faults (`None` past the planned horizon).
    pub fn round(&self, r: usize) -> Option<&RoundFaults> {
        self.rounds.get(r)
    }

    /// Total injected events across the plan.
    pub fn n_injected(&self) -> usize {
        self.rounds.iter().map(|r| r.n_injected()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_parse_roundtrip() {
        let evs = FaultSpec::parse_events(
            "crash@3:1, delay@4:0:2.5,corrupt@5:2,abort@6",
        )
        .unwrap();
        assert_eq!(evs.len(), 4);
        assert_eq!(
            evs[0],
            FaultEvent { round: 3, kind: FaultKind::ClientCrash(1) }
        );
        assert_eq!(
            evs[1],
            FaultEvent {
                round: 4,
                kind: FaultKind::DelayedUplink { client: 0, delay_s: 2.5 }
            }
        );
        assert_eq!(
            evs[2],
            FaultEvent { round: 5, kind: FaultKind::CorruptPayload(2) }
        );
        assert_eq!(
            evs[3],
            FaultEvent { round: 6, kind: FaultKind::ServerAbort }
        );
        assert!(FaultSpec::parse_events("").unwrap().is_empty());
        assert!(FaultSpec::parse_events("boom@1:2").is_err());
        assert!(FaultSpec::parse_events("crash@x:2").is_err());
        assert!(FaultSpec::parse_events("crash@1").is_err());
        assert!(FaultSpec::parse_events("abort@1:2").is_err());
        assert!(FaultSpec::parse_events("delay@1:2:zzz").is_err());
    }

    #[test]
    fn validation_bounds() {
        let mut s = FaultSpec::default();
        assert!(s.validate(3, 10).is_ok());
        s.crash_prob = 1.5;
        assert!(s.validate(3, 10).is_err());
        let mut s = FaultSpec::default();
        s.quorum = 4;
        assert!(s.validate(3, 10).is_err());
        assert!(s.validate(4, 10).is_ok());
        let mut s = FaultSpec::default();
        s.deadline_factor = 0.5;
        assert!(s.validate(3, 10).is_err());
        let mut s = FaultSpec::default();
        s.events = FaultSpec::parse_events("crash@12:0").unwrap();
        assert!(s.validate(3, 10).is_err(), "round beyond run");
        s.events = FaultSpec::parse_events("crash@2:9").unwrap();
        assert!(s.validate(3, 10).is_err(), "client beyond roster");
        s.events = FaultSpec::parse_events("crash@2:2").unwrap();
        assert!(s.validate(3, 10).is_ok());
    }

    #[test]
    fn scheduled_expansion_consumes_no_rng() {
        let mut spec = FaultSpec::default();
        spec.events =
            FaultSpec::parse_events("crash@1:0,abort@2,delay@0:1:0.25")
                .unwrap();
        let mut rng = Rng::new(9);
        let mut witness = rng.clone();
        let plan = spec.expand(4, 2, &mut rng).unwrap();
        assert_eq!(rng.next_u64(), witness.next_u64(), "stream moved");
        assert_eq!(plan.rounds.len(), 4);
        assert_eq!(plan.rounds[1].crashed, vec![0]);
        assert!(plan.rounds[2].server_abort);
        assert_eq!(plan.rounds[0].delays, vec![(1, 0.25)]);
        assert_eq!(plan.n_injected(), 3);
    }

    #[test]
    fn random_expansion_is_seed_deterministic() {
        let mut spec = FaultSpec::default();
        spec.crash_prob = 0.3;
        spec.abort_prob = 0.2;
        let a = spec.expand(20, 4, &mut Rng::new(5)).unwrap();
        let b = spec.expand(20, 4, &mut Rng::new(5)).unwrap();
        assert_eq!(a, b);
        let c = spec.expand(20, 4, &mut Rng::new(6)).unwrap();
        assert_ne!(a, c, "different seed must move the plan");
        assert!(a.n_injected() > 0, "p=0.3 over 80 draws hit nothing");
    }

    #[test]
    fn feature_streams_are_independent() {
        // Enabling delays must not move the crash draws (private
        // sub-streams off one base, scenario-engine discipline).
        let mut only_crash = FaultSpec::default();
        only_crash.crash_prob = 0.4;
        let mut both = only_crash.clone();
        both.delay_prob = 0.5;
        let a = only_crash.expand(12, 3, &mut Rng::new(11)).unwrap();
        let b = both.expand(12, 3, &mut Rng::new(11)).unwrap();
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            // Crash wins over delay during normalization, so compare on
            // the crash sets only.
            assert_eq!(ra.crashed, rb.crashed);
        }
    }

    #[test]
    fn crash_dominates_same_client_faults() {
        let mut spec = FaultSpec::default();
        spec.events = FaultSpec::parse_events(
            "crash@0:1,delay@0:1:2.0,corrupt@0:1,corrupt@0:0",
        )
        .unwrap();
        let plan = spec.expand(1, 2, &mut Rng::new(1)).unwrap();
        let rf = &plan.rounds[0];
        assert_eq!(rf.crashed, vec![1]);
        assert!(rf.delays.is_empty(), "delay on crashed client kept");
        assert_eq!(rf.corrupt, vec![0], "corrupt on crashed client kept");
    }

    #[test]
    fn settings_to_spec() {
        let mut st = FaultSettings::default();
        st.events = "abort@1".into();
        st.crash_prob = 0.1;
        st.quorum = 2;
        let spec = FaultSpec::from_settings(&st).unwrap();
        assert_eq!(spec.events.len(), 1);
        assert_eq!(spec.crash_prob, 0.1);
        assert_eq!(spec.quorum, 2);
        st.corrupt_prob = -0.5;
        assert!(FaultSpec::from_settings(&st).is_err());
    }
}
