//! Evaluate a re-optimization policy along an expanded [`Scenario`].
//!
//! The scenario is partitioned into *blocks*: a block starts at every
//! round where the policy re-solves (round 0, every k-th round for
//! [`ReoptPolicy::EveryK`], and every membership change, which forces a
//! re-solve under any policy). Each block runs one BCD solve and then
//! evaluates the resulting decision against every round in the block on
//! the [`Evaluator`] fast path (`optim::eval`).
//!
//! For `Never` / `EveryK` the block boundaries are known up front, every
//! block is a pure function of the scenario, and the blocks fan across
//! cores via [`par::parallel_map`] — results are **bit-identical** to the
//! serial loop for any thread count (`EPSL_THREADS=1` forces serial).
//! [`ReoptPolicy::OnRegression`] is inherently sequential (whether round
//! r re-solves depends on round r−1's outcome) and always runs serially.
//!
//! Solve bases mirror the paper's semantics: `Never` / `OnRegression`
//! optimize on the *average* gains of the current deployment (resource
//! management as deployed), while `EveryK` re-optimizes on the round's
//! *realized* gains (`EveryK(1)` is exactly the Fig. 13 oracle).

use crate::channel::ChannelRealization;
use crate::latency::frameworks::Framework;
use crate::latency::LatencyInputs;
use crate::optim::eval::Evaluator;
use crate::optim::{bcd, CutAssignment, Decision, Problem};
use crate::profile::NetworkProfile;
use crate::timeline::{self, Mode};
use crate::util::par;
use crate::util::stats::mean;

use super::engine::{Scenario, ScenarioRound};
use super::spec::ReoptPolicy;

/// One policy run's knobs.
#[derive(Debug, Clone, Copy)]
pub struct RunOptions {
    pub policy: ReoptPolicy,
    pub bcd: bcd::BcdOptions,
    /// Mini-batch size b of the latency model.
    pub batch: usize,
    /// Aggregation ratio φ of the latency model.
    pub phi: f64,
    /// Worker threads for the block fan-out (`OnRegression` ignores this
    /// and runs serially).
    pub threads: usize,
    /// How per-round latency is accounted: `Barrier` evaluates the
    /// eq. 23 closed form on the `optim::eval` fast path (bit-identical
    /// to the legacy pipeline); `Pipelined` runs the round's realized
    /// rates through the timeline engine's overlapped schedule.
    pub timeline_mode: Mode,
}

impl RunOptions {
    pub fn new(policy: ReoptPolicy, batch: usize, phi: f64) -> RunOptions {
        RunOptions {
            policy,
            bcd: bcd::BcdOptions::default(),
            batch,
            phi,
            threads: 1,
            timeline_mode: Mode::Barrier,
        }
    }
}

/// One round's result under the policy.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    pub round: usize,
    /// Eq. 23 latency of the decision in force, on this round's realized
    /// deployment + channel. `None` when the governing solve failed.
    pub latency: Option<f64>,
    /// Did the optimizer (re-)solve at this round?
    pub reoptimized: bool,
}

/// Per-round link state for latency consumers (the training driver's
/// dynamic-channel `SimLatency`).
#[derive(Debug, Clone)]
pub struct RoundRates {
    pub cut: CutAssignment,
    pub f_clients: Vec<f64>,
    pub uplink: Vec<f64>,
    pub downlink: Vec<f64>,
    pub broadcast: f64,
}

/// A full policy run.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub rounds: Vec<RoundOutcome>,
    /// Optimizer invocations along the run (failed solves included).
    pub n_solves: usize,
    /// Rounds left without a latency because their solve failed.
    pub n_failed: usize,
}

impl ScenarioOutcome {
    /// Per-round latencies in round order (`None` = failed solve).
    pub fn latencies(&self) -> Vec<Option<f64>> {
        self.rounds.iter().map(|r| r.latency).collect()
    }

    /// Mean over the successfully evaluated rounds.
    pub fn mean_latency(&self) -> f64 {
        let vals: Vec<f64> =
            self.rounds.iter().filter_map(|r| r.latency).collect();
        mean(&vals)
    }
}

fn round_problem<'a>(sc: &'a Scenario, profile: &'a NetworkProfile,
                     round: &'a ScenarioRound, opts: &RunOptions)
    -> Problem<'a> {
    Problem {
        cfg: &sc.net,
        profile,
        dep: &round.dep,
        ch: &round.ch,
        batch: opts.batch,
        phi: opts.phi,
    }
}

/// Evaluate `d` on one round. Barrier mode: fast-path rates + eq. 23
/// objective (bit-identical to `Evaluator::objective`, which is
/// bit-identical to the reference `Problem::objective`). Pipelined mode:
/// the same realized rates run through the timeline engine's overlapped
/// schedule (≤ the barrier value, exactly).
fn eval_round(sc: &Scenario, profile: &NetworkProfile,
              round: &ScenarioRound, d: &Decision, opts: &RunOptions)
    -> (f64, RoundRates) {
    let prob = round_problem(sc, profile, round, opts);
    let ev = Evaluator::new(&prob);
    let mut up = Vec::new();
    let mut dn = Vec::new();
    ev.fill_rates(&d.alloc, &d.psd_dbm_hz, &mut up, &mut dn);
    let rates = RoundRates {
        cut: d.cut.clone(),
        f_clients: round.dep.f_clients().to_vec(),
        uplink: up,
        downlink: dn,
        broadcast: ev.broadcast_rate(),
    };
    let t = match (opts.timeline_mode, d.cut.as_uniform()) {
        (Mode::Barrier, Some(j)) => {
            ev.objective_with_rates(j, &rates.uplink, &rates.downlink)
        }
        (Mode::Barrier, None) => ev.objective_with_rates_cuts(
            &d.cut.cuts_for(prob.n_clients()),
            &rates.uplink,
            &rates.downlink,
        ),
        (Mode::Pipelined, uni) => {
            let inp = LatencyInputs {
                profile,
                cut: d.cut.min_cut(),
                batch: opts.batch,
                phi: opts.phi,
                f_server: sc.net.f_server,
                kappa_server: sc.net.kappa_server,
                kappa_client: sc.net.kappa_client,
                f_clients: &rates.f_clients,
                uplink: &rates.uplink,
                downlink: &rates.downlink,
                broadcast: rates.broadcast,
                uplink_comp: sc.net.uplink_compression,
            };
            let fw = Framework::Epsl { phi: opts.phi };
            match uni {
                Some(j) => timeline::simulate(
                    fw,
                    &LatencyInputs { cut: j, ..inp },
                    Mode::Pipelined,
                )
                .total,
                // shape_for_cuts only fails for exchange frameworks,
                // never for EPSL.
                None => timeline::simulate_cuts(
                    fw,
                    &inp,
                    &d.cut.cuts_for(prob.n_clients()),
                    Mode::Pipelined,
                )
                .map(|t| t.total)
                .unwrap_or(f64::INFINITY),
            }
        }
    };
    (t, rates)
}

/// Solve at `round` on the policy's basis gains (realized for `EveryK`,
/// current averages otherwise).
fn solve_at(sc: &Scenario, profile: &NetworkProfile, round: &ScenarioRound,
            opts: &RunOptions) -> Option<Decision> {
    let avg;
    let basis_ch: &ChannelRealization = match opts.policy {
        ReoptPolicy::EveryK(_) => &round.ch,
        _ => {
            avg = ChannelRealization::average(&round.dep);
            &avg
        }
    };
    let prob = Problem {
        cfg: &sc.net,
        profile,
        dep: &round.dep,
        ch: basis_ch,
        batch: opts.batch,
        phi: opts.phi,
    };
    bcd::solve(&prob, opts.bcd).ok().map(|r| r.decision)
}

/// Rounds where the policy re-solves (`Never` / `EveryK` only; membership
/// changes force a solve under every policy).
fn solve_points(sc: &Scenario, policy: ReoptPolicy) -> Vec<usize> {
    let mut pts = vec![0];
    for r in 1..sc.n_rounds() {
        let periodic =
            matches!(policy, ReoptPolicy::EveryK(k) if r % k == 0);
        if periodic || sc.rounds[r].membership_changed {
            pts.push(r);
        }
    }
    pts
}

/// One block's outcomes + rates (pure function of the scenario).
fn eval_block(sc: &Scenario, profile: &NetworkProfile, opts: &RunOptions,
              start: usize, end: usize)
    -> Vec<(RoundOutcome, Option<RoundRates>)> {
    let mut out = Vec::with_capacity(end - start);
    match solve_at(sc, profile, &sc.rounds[start], opts) {
        Some(d) => {
            for r in start..end {
                let (t, rates) =
                    eval_round(sc, profile, &sc.rounds[r], &d, opts);
                out.push((
                    RoundOutcome {
                        round: r,
                        latency: Some(t),
                        reoptimized: r == start,
                    },
                    Some(rates),
                ));
            }
        }
        None => {
            for r in start..end {
                out.push((
                    RoundOutcome {
                        round: r,
                        latency: None,
                        reoptimized: r == start,
                    },
                    None,
                ));
            }
        }
    }
    out
}

/// Run the policy over the scenario; see the module docs for the block
/// decomposition and determinism contract.
pub fn run_policy(sc: &Scenario, profile: &NetworkProfile,
                  opts: &RunOptions) -> ScenarioOutcome {
    run_policy_with_rates(sc, profile, opts).0
}

/// [`run_policy`] variant that also returns per-round link rates for the
/// training driver's dynamic-channel latency accounting (`None` for
/// rounds whose solve failed).
pub fn run_policy_with_rates(sc: &Scenario, profile: &NetworkProfile,
                             opts: &RunOptions)
    -> (ScenarioOutcome, Vec<Option<RoundRates>>) {
    if let ReoptPolicy::OnRegression(threshold) = opts.policy {
        return run_on_regression(sc, profile, opts, threshold);
    }
    let pts = solve_points(sc, opts.policy);
    let n = sc.n_rounds();
    let blocks: Vec<(usize, usize)> = pts
        .iter()
        .enumerate()
        .map(|(i, &s)| (s, pts.get(i + 1).copied().unwrap_or(n)))
        .collect();
    let results = par::parallel_map(&blocks, opts.threads, |_, &(s, e)| {
        eval_block(sc, profile, opts, s, e)
    });
    let n_solves = blocks.len();
    let mut rounds = Vec::with_capacity(n);
    let mut rates = Vec::with_capacity(n);
    for block in results {
        for (o, r) in block {
            rounds.push(o);
            rates.push(r);
        }
    }
    let n_failed = rounds.iter().filter(|r| r.latency.is_none()).count();
    (ScenarioOutcome { rounds, n_solves, n_failed }, rates)
}

/// Serial `OnRegression` loop: evaluate with the incumbent; if the round
/// regressed past `threshold ×` the latency recorded at the last solve,
/// re-solve on the round's realized gains and re-evaluate.
fn run_on_regression(sc: &Scenario, profile: &NetworkProfile,
                     opts: &RunOptions, threshold: f64)
    -> (ScenarioOutcome, Vec<Option<RoundRates>>) {
    let mut rounds = Vec::with_capacity(sc.n_rounds());
    let mut rates = Vec::with_capacity(sc.n_rounds());
    let mut incumbent: Option<Decision> = None;
    let mut baseline = f64::INFINITY;
    let mut n_solves = 0usize;
    for round in &sc.rounds {
        let mut reoptimized = false;
        if incumbent.is_none() || round.membership_changed {
            n_solves += 1;
            reoptimized = true;
            incumbent = solve_at(sc, profile, round, opts);
            baseline = f64::INFINITY; // reset on the first evaluation below
        }
        let current = incumbent.clone();
        let (latency, rate) = match current {
            None => (None, None),
            Some(d) => {
                let (mut t, mut r) =
                    eval_round(sc, profile, round, &d, opts);
                if baseline.is_finite() && t > threshold * baseline {
                    // Regressed: re-solve on this round's realized gains.
                    n_solves += 1;
                    reoptimized = true;
                    let realized =
                        round_problem(sc, profile, round, opts);
                    if let Ok(res) = bcd::solve(&realized, opts.bcd) {
                        let d2 = res.decision;
                        let (t2, r2) =
                            eval_round(sc, profile, round, &d2, opts);
                        t = t2;
                        r = r2;
                        baseline = t2;
                        incumbent = Some(d2);
                    }
                } else if !baseline.is_finite() {
                    baseline = t;
                }
                (Some(t), Some(r))
            }
        };
        rounds.push(RoundOutcome { round: round.round, latency, reoptimized });
        rates.push(rate);
    }
    let n_failed = rounds.iter().filter(|r| r.latency.is_none()).count();
    (ScenarioOutcome { rounds, n_solves, n_failed }, rates)
}

/// Paired fixed/oracle statistics over a shared realization sequence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PairedStats {
    pub fixed_mean: f64,
    pub oracle_mean: f64,
    /// Realizations where both sides evaluated.
    pub n_pairs: usize,
    /// Realizations dropped from *both* means because either side failed.
    pub n_dropped: usize,
}

impl PairedStats {
    /// fixed/oracle latency ratio (the Fig. 13 robustness number).
    pub fn ratio(&self) -> f64 {
        self.fixed_mean / self.oracle_mean.max(1e-12)
    }
}

/// Pair per-realization fixed/oracle latencies, dropping **both** halves
/// of any realization where either side failed, so the two means always
/// average the same realization set. (The pre-scenario Fig. 13 silently
/// `.flatten()`-ed oracle failures, letting the fixed and oracle means
/// average over different realizations.)
pub fn pair_latencies(fixed: &[Option<f64>], oracle: &[Option<f64>])
    -> PairedStats {
    debug_assert_eq!(
        fixed.len(),
        oracle.len(),
        "paired series must cover the same realizations"
    );
    let mut f_vals = Vec::with_capacity(fixed.len());
    let mut o_vals = Vec::with_capacity(oracle.len());
    let mut n_dropped = 0usize;
    for (f, o) in fixed.iter().zip(oracle) {
        match (f, o) {
            (Some(fv), Some(ov)) => {
                f_vals.push(*fv);
                o_vals.push(*ov);
            }
            _ => n_dropped += 1,
        }
    }
    // No surviving pair ⇒ NaN means (not a silent 0.0-second latency).
    let (fixed_mean, oracle_mean) = if f_vals.is_empty() {
        (f64::NAN, f64::NAN)
    } else {
        (mean(&f_vals), mean(&o_vals))
    };
    PairedStats {
        fixed_mean,
        oracle_mean,
        n_pairs: f_vals.len(),
        n_dropped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::profile::resnet18;
    use crate::scenario::spec::ScenarioSpec;

    fn small_net() -> NetworkConfig {
        NetworkConfig::default().with_clients(3)
    }

    fn fading_scenario(rounds: usize, seed: u64) -> Scenario {
        Scenario::generate(&small_net(), &ScenarioSpec::fading(rounds), seed)
            .unwrap()
    }

    fn opts(policy: ReoptPolicy, threads: usize) -> RunOptions {
        RunOptions {
            policy,
            bcd: bcd::BcdOptions { max_iters: 4, tol: 1e-4 },
            batch: 64,
            phi: 0.5,
            threads,
            timeline_mode: Mode::Barrier,
        }
    }

    #[test]
    fn never_on_static_scenario_is_constant() {
        let sc = Scenario::generate(
            &small_net(),
            &ScenarioSpec::static_channel(6),
            5,
        )
        .unwrap();
        let profile = resnet18::profile();
        let out = run_policy(&sc, &profile, &opts(ReoptPolicy::Never, 1));
        assert_eq!(out.n_solves, 1);
        assert_eq!(out.n_failed, 0);
        assert_eq!(out.rounds.len(), 6);
        let t0 = out.rounds[0].latency.unwrap();
        assert!(t0 > 0.0);
        for r in &out.rounds {
            assert_eq!(r.latency.unwrap().to_bits(), t0.to_bits());
            assert_eq!(r.reoptimized, r.round == 0);
        }
    }

    #[test]
    fn every_k_counts_solves() {
        let sc = fading_scenario(12, 0xE7);
        let profile = resnet18::profile();
        let out =
            run_policy(&sc, &profile, &opts(ReoptPolicy::EveryK(4), 1));
        assert_eq!(out.n_solves, 3, "solves at rounds 0, 4, 8");
        let solved: Vec<usize> = out
            .rounds
            .iter()
            .filter(|r| r.reoptimized)
            .map(|r| r.round)
            .collect();
        assert_eq!(solved, vec![0, 4, 8]);
    }

    #[test]
    fn parallel_blocks_bit_identical_to_serial() {
        let sc = fading_scenario(10, 0xDE7);
        let profile = resnet18::profile();
        for policy in [ReoptPolicy::Never, ReoptPolicy::EveryK(3)] {
            let serial = run_policy(&sc, &profile, &opts(policy, 1));
            for threads in [2, 4, 8] {
                let par = run_policy(&sc, &profile, &opts(policy, threads));
                assert_eq!(serial.n_solves, par.n_solves);
                for (a, b) in serial.rounds.iter().zip(&par.rounds) {
                    assert_eq!(
                        a.latency.map(f64::to_bits),
                        b.latency.map(f64::to_bits),
                        "round {} diverged at {threads} threads",
                        a.round
                    );
                }
            }
        }
    }

    // The EveryK(1)-vs-legacy-oracle bit-parity check lives in
    // `experiments::sweep::tests::oracle_matches_scenario_every_round`:
    // scenario sits below experiments in the layering DAG, so the
    // cross-layer test belongs to the higher layer.

    #[test]
    fn on_regression_with_huge_threshold_acts_like_never() {
        let sc = fading_scenario(8, 0x0A);
        let profile = resnet18::profile();
        let out = run_policy(
            &sc,
            &profile,
            &opts(ReoptPolicy::OnRegression(1e9), 1),
        );
        assert_eq!(out.n_solves, 1);
        assert_eq!(out.n_failed, 0);
        let fixed =
            run_policy(&sc, &profile, &opts(ReoptPolicy::Never, 1));
        // Same initial solve basis (average gains) → same decision: the
        // evaluated rounds agree bit-for-bit (no regression ever fires).
        for (a, b) in out.rounds.iter().zip(&fixed.rounds) {
            assert_eq!(
                a.latency.map(f64::to_bits),
                b.latency.map(f64::to_bits)
            );
        }
    }

    #[test]
    fn on_regression_is_deterministic() {
        let sc = fading_scenario(10, 0x5EED);
        let profile = resnet18::profile();
        let a = run_policy(
            &sc,
            &profile,
            &opts(ReoptPolicy::OnRegression(1.05), 4),
        );
        let b = run_policy(
            &sc,
            &profile,
            &opts(ReoptPolicy::OnRegression(1.05), 1),
        );
        assert_eq!(a.n_solves, b.n_solves);
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(
                x.latency.map(f64::to_bits),
                y.latency.map(f64::to_bits)
            );
        }
        assert!(a.n_solves >= 1);
        assert_eq!(a.n_failed, 0);
    }

    #[test]
    fn pipelined_rounds_never_slower_than_barrier() {
        // Same scenario, same decisions, same realized rates — the only
        // difference is the timeline schedule. Every round must satisfy
        // pipelined ≤ barrier, and the Table-III heterogeneity makes the
        // run strictly faster in aggregate.
        let sc = fading_scenario(6, 0x71E);
        let profile = resnet18::profile();
        let barrier =
            run_policy(&sc, &profile, &opts(ReoptPolicy::Never, 1));
        let mut po = opts(ReoptPolicy::Never, 1);
        po.timeline_mode = Mode::Pipelined;
        let pipelined = run_policy(&sc, &profile, &po);
        assert_eq!(barrier.rounds.len(), pipelined.rounds.len());
        let mut sum_b = 0.0;
        let mut sum_p = 0.0;
        for (a, b) in barrier.rounds.iter().zip(&pipelined.rounds) {
            let (ta, tb) = (a.latency.unwrap(), b.latency.unwrap());
            assert!(
                tb <= ta,
                "round {}: pipelined {tb} > barrier {ta}",
                a.round
            );
            sum_b += ta;
            sum_p += tb;
        }
        assert!(sum_p < sum_b, "no pipelining gain: {sum_p} vs {sum_b}");
    }

    #[test]
    fn rates_variant_fills_rates() {
        let sc = fading_scenario(4, 3);
        let profile = resnet18::profile();
        let (out, rates) = run_policy_with_rates(
            &sc,
            &profile,
            &opts(ReoptPolicy::Never, 1),
        );
        assert_eq!(rates.len(), out.rounds.len());
        for r in rates.iter().flatten() {
            assert_eq!(r.uplink.len(), 3);
            assert_eq!(r.downlink.len(), 3);
            assert_eq!(r.f_clients.len(), 3);
            assert!(r.broadcast > 0.0);
            assert!(r.uplink.iter().all(|v| *v > 0.0));
        }
    }

    #[test]
    fn pair_latencies_drops_both_halves() {
        let fixed = vec![Some(2.0), Some(4.0), Some(6.0), None];
        let oracle = vec![Some(1.0), None, Some(3.0), Some(9.0)];
        let p = pair_latencies(&fixed, &oracle);
        // Realizations 1 and 3 drop entirely: means over {0, 2} only.
        assert_eq!(p.n_pairs, 2);
        assert_eq!(p.n_dropped, 2);
        assert_eq!(p.fixed_mean, 4.0);
        assert_eq!(p.oracle_mean, 2.0);
        assert_eq!(p.ratio(), 2.0);
        // The pre-fix `.flatten()` would have averaged the fixed mean
        // over {2,4,6}=4 and the oracle mean over {1,3,9}≈4.33 — unpaired
        // sets. The paired means must differ from that.
        let unpaired_oracle = (1.0 + 3.0 + 9.0) / 3.0;
        assert!((p.oracle_mean - unpaired_oracle).abs() > 1.0);
    }

    #[test]
    fn pair_latencies_empty_pairing_is_nan_not_zero() {
        // All realizations dropped ⇒ NaN means and NaN ratio, never a
        // silent 0.000-second latency row.
        let p = pair_latencies(&[None, Some(1.0)], &[Some(2.0), None]);
        assert_eq!(p.n_pairs, 0);
        assert_eq!(p.n_dropped, 2);
        assert!(p.fixed_mean.is_nan());
        assert!(p.oracle_mean.is_nan());
        assert!(p.ratio().is_nan());
    }

    #[test]
    fn pair_latencies_all_good_matches_plain_means() {
        let fixed = vec![Some(1.0), Some(3.0)];
        let oracle = vec![Some(0.5), Some(1.5)];
        let p = pair_latencies(&fixed, &oracle);
        assert_eq!(p.n_dropped, 0);
        assert_eq!(p.fixed_mean, 2.0);
        assert_eq!(p.oracle_mean, 1.0);
    }
}
