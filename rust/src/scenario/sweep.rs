//! Parallel sweep over scenario cells — the Fig. 13b-style grids.
//!
//! One [`ScenarioCell`] is a full experiment: expand a scenario from the
//! cell's own seed, run one re-optimization policy along it, and summarize.
//! Cells are independent and carry all of their randomness in the cell
//! itself, so a batch fans across cores via [`par::parallel_map`] with
//! results **bit-identical** to the serial loop for any thread count
//! (`EPSL_THREADS=1` forces serial). Each cell runs its own policy loop
//! serially (`threads: 1`) — the parallelism lives at the grid level,
//! matching the Figs. 9–12 sweep engine.

use crate::config::NetworkConfig;
use crate::optim::bcd::BcdOptions;
use crate::profile::NetworkProfile;
use crate::timeline::Mode;
use crate::util::par;

use super::engine::Scenario;
use super::run::{run_policy, RunOptions};
use super::spec::{ReoptPolicy, ScenarioSpec};

/// One (spec × policy × seed) cell.
#[derive(Debug, Clone)]
pub struct ScenarioCell {
    pub net: NetworkConfig,
    pub spec: ScenarioSpec,
    pub policy: ReoptPolicy,
    pub bcd: BcdOptions,
    /// Seed for the roster draw + scenario expansion.
    pub seed: u64,
    pub batch: usize,
    pub phi: f64,
    /// Timeline mode for the per-round latency accounting.
    pub timeline_mode: Mode,
}

/// Aggregate result of one cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScenarioSummary {
    /// Mean eq. 23 latency over the evaluated rounds.
    pub mean_latency: f64,
    /// Optimizer invocations along the run.
    pub n_solves: usize,
    /// Rounds dropped because their governing solve failed.
    pub n_failed: usize,
    /// Rounds that entered the mean.
    pub n_rounds: usize,
}

/// Evaluate one cell (`None` if the spec is invalid for the drawn roster).
pub fn eval_scenario_cell(profile: &NetworkProfile, cell: &ScenarioCell)
    -> Option<ScenarioSummary> {
    let sc = Scenario::generate(&cell.net, &cell.spec, cell.seed).ok()?;
    let out = run_policy(
        &sc,
        profile,
        &RunOptions {
            policy: cell.policy,
            bcd: cell.bcd,
            batch: cell.batch,
            phi: cell.phi,
            threads: 1,
            timeline_mode: cell.timeline_mode,
        },
    );
    Some(ScenarioSummary {
        mean_latency: out.mean_latency(),
        n_solves: out.n_solves,
        n_failed: out.n_failed,
        n_rounds: out.rounds.len() - out.n_failed,
    })
}

/// Fan a batch of scenario cells across `threads` workers; results come
/// back in input order.
pub fn run_scenario_cells(profile: &NetworkProfile, cells: &[ScenarioCell],
                          threads: usize) -> Vec<Option<ScenarioSummary>> {
    par::parallel_map(cells, threads, |_, cell| {
        eval_scenario_cell(profile, cell)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::resnet18;

    fn cells() -> Vec<ScenarioCell> {
        let net = NetworkConfig::default().with_clients(3);
        let mut cells = Vec::new();
        for policy in [
            ReoptPolicy::Never,
            ReoptPolicy::EveryK(2),
            ReoptPolicy::OnRegression(1.1),
        ] {
            for s in 0..2u64 {
                cells.push(ScenarioCell {
                    net: net.clone(),
                    spec: ScenarioSpec::fading(6),
                    policy,
                    bcd: BcdOptions { max_iters: 4, tol: 1e-4 },
                    seed: 0x13B + s,
                    batch: 64,
                    phi: 0.5,
                    timeline_mode: Mode::Barrier,
                });
            }
        }
        cells
    }

    #[test]
    fn scenario_cells_bit_identical_across_threads() {
        let profile = resnet18::profile();
        let cells = cells();
        let serial = run_scenario_cells(&profile, &cells, 1);
        for threads in [3, 8] {
            let par_out = run_scenario_cells(&profile, &cells, threads);
            assert_eq!(serial.len(), par_out.len());
            for (i, (a, b)) in serial.iter().zip(&par_out).enumerate() {
                match (a, b) {
                    (Some(x), Some(y)) => {
                        assert_eq!(
                            x.mean_latency.to_bits(),
                            y.mean_latency.to_bits(),
                            "cell {i} diverged at {threads} threads"
                        );
                        assert_eq!(x.n_solves, y.n_solves);
                        assert_eq!(x.n_failed, y.n_failed);
                    }
                    (None, None) => {}
                    _ => panic!("cell {i}: success/failure diverged"),
                }
            }
        }
        assert!(serial.iter().all(|c| c.is_some()));
    }

    #[test]
    fn invalid_spec_yields_none() {
        let profile = resnet18::profile();
        let cell = ScenarioCell {
            net: NetworkConfig::default().with_clients(3),
            spec: ScenarioSpec::static_channel(0), // rounds=0 is invalid
            policy: ReoptPolicy::Never,
            bcd: BcdOptions::default(),
            seed: 1,
            batch: 64,
            phi: 0.5,
            timeline_mode: Mode::Barrier,
        };
        assert!(eval_scenario_cell(&profile, &cell).is_none());
    }
}
