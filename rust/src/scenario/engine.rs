//! Deterministic expansion of a [`ScenarioSpec`] into per-round network
//! states.
//!
//! The expansion is serial and cheap (the expensive part — BCD solves and
//! objective evaluations — happens in [`super::run`]); all randomness flows
//! through the caller's [`Rng`] with one documented stream discipline:
//!
//! - when any of churn / LoS flips / compute jitter is enabled, exactly
//!   one base stream is forked from the parent, and every enabled feature
//!   derives its private sub-stream from a *clone* of that base with its
//!   own tag — so the fading draws and each feature's draws are identical
//!   no matter which other features are toggled;
//! - block-fading redraws consume the parent stream directly, and a
//!   feature-free spec forks nothing, which keeps a pure-fading spec
//!   ([`ScenarioSpec::fading`]) on the **exact** RNG stream the
//!   pre-scenario Fig. 13 loop used (`n` sequential
//!   [`ChannelRealization::sample`] calls after the deployment draw) — the
//!   refactored figure reproduces its numbers bit-for-bit.
//!
//! Round 0 is always the deployment as generated (dynamics start at round
//! 1); under `redraw_period: Some(k)` the fading is redrawn at rounds
//! `0, k, 2k, …` and held between redraws (block fading).

use crate::channel::{ChannelRealization, ClientLink, Deployment};
use crate::channel::pathloss;
use crate::config::NetworkConfig;
use crate::error::Result;
use crate::util::rng::{streams, Rng};

use super::spec::ScenarioSpec;

/// One round's realized network state.
#[derive(Debug, Clone)]
pub struct ScenarioRound {
    pub round: usize,
    /// Deployment the round sees: active clients only, with this round's
    /// LoS states and jittered compute capabilities.
    pub dep: Deployment,
    /// Channel gains the round experiences (rows follow `dep.clients`).
    pub ch: ChannelRealization,
    /// Roster indices of the active clients (`dep.clients[j]` is roster
    /// client `active[j]`).
    pub active: Vec<usize>,
    /// Did the active client set change vs. the previous round? (Forces a
    /// re-solve: the incumbent allocation maps subchannels to a client set
    /// that no longer exists.)
    pub membership_changed: bool,
}

/// A fully expanded scenario: the roster deployment plus every round's
/// realized state.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub net: NetworkConfig,
    pub spec: ScenarioSpec,
    /// The generated roster (round-0 deployment; churn activates subsets
    /// of it).
    pub roster: Deployment,
    pub rounds: Vec<ScenarioRound>,
}

impl Scenario {
    /// Draw a fresh roster from `net` and expand `spec` — everything from
    /// one seed.
    pub fn generate(net: &NetworkConfig, spec: &ScenarioSpec, seed: u64)
        -> Result<Scenario> {
        let mut rng = Rng::new(seed);
        let roster = Deployment::generate(net, &mut rng);
        Scenario::from_deployment(net.clone(), roster, spec.clone(), &mut rng)
    }

    /// Expand `spec` over an existing deployment, continuing the caller's
    /// RNG stream (the Fig. 13 entry point: the figure draws the
    /// deployment itself, then hands the same `rng` over).
    pub fn from_deployment(net: NetworkConfig, roster: Deployment,
                           spec: ScenarioSpec, rng: &mut Rng)
        -> Result<Scenario> {
        spec.validate(roster.n_clients())?;
        let c = roster.n_clients();

        // Feature sub-streams (see module docs): one base fork when any
        // feature is enabled; each feature derives from a clone of it, so
        // its draws don't depend on which other features are on.
        let any_feature = spec.churn.is_some()
            || spec.los_flip.is_some()
            || spec.compute_jitter.is_some();
        let base = any_feature.then(|| rng.fork(streams::SCENARIO_DYNAMICS));
        // A feature's stream exists iff the base does (the feature being
        // on implies `any_feature`), so this is expect-free by shape.
        let sub = |tag: u64| {
            base.as_ref().map(|b| {
                let mut b = b.clone();
                b.fork(tag)
            })
        };
        let mut churn_rng =
            if spec.churn.is_some() { sub(streams::SCENARIO_CHURN) } else { None };
        let mut los_rng =
            if spec.los_flip.is_some() { sub(streams::SCENARIO_LOS) } else { None };
        let mut jit_rng = if spec.compute_jitter.is_some() {
            sub(streams::SCENARIO_JITTER)
        } else {
            None
        };

        let base_f: Vec<f64> = roster.f_clients().to_vec();
        let mut los: Vec<bool> = roster.clients.iter().map(|l| l.los).collect();
        let mut active = vec![true; c];
        let mut f_now = base_f.clone();
        // Full-roster gains of the current fading block (set at round 0).
        let mut block_gains: Vec<Vec<f64>> = Vec::new();

        let mut rounds = Vec::with_capacity(spec.rounds);
        for r in 0..spec.rounds {
            let mut membership_changed = false;
            if r > 0 {
                // 1. Churn: roster-index order; an active client may drop
                //    (never below min_active), an inactive one may rejoin.
                if let (Some(cs), Some(crng)) =
                    (spec.churn.as_ref(), churn_rng.as_mut())
                {
                    let mut n_active =
                        active.iter().filter(|a| **a).count();
                    for slot in active.iter_mut() {
                        if *slot {
                            if crng.chance(cs.drop_prob)
                                && n_active > cs.min_active
                            {
                                *slot = false;
                                n_active -= 1;
                                membership_changed = true;
                            }
                        } else if crng.chance(cs.rejoin_prob) {
                            *slot = true;
                            n_active += 1;
                            membership_changed = true;
                        }
                    }
                }
                // 2. LoS Markov flips (drawn for every roster client, so
                //    the stream is independent of churn outcomes).
                if let (Some(fs), Some(lrng)) =
                    (spec.los_flip.as_ref(), los_rng.as_mut())
                {
                    for i in 0..c {
                        let p_los = pathloss::los_probability(
                            roster.clients[i].distance_m,
                        );
                        let p = if los[i] {
                            fs.flip_prob * (1.0 - p_los)
                        } else {
                            fs.flip_prob * p_los
                        };
                        if lrng.chance(p) {
                            los[i] = !los[i];
                            // A flip changes the deterministic pathloss
                            // immediately: rescale the held block-fading
                            // row (keeping its shadowing realization) so
                            // `ch` always agrees with `dep`'s LoS state
                            // mid-block. The next redraw resamples fully;
                            // the `None` (average-gain) branch recomputes
                            // from `dep` every round anyway.
                            if spec.redraw_period.is_some() {
                                let d = roster.clients[i].distance_m;
                                for (k, s) in
                                    roster.subchannels.iter().enumerate()
                                {
                                    let old_mean = pathloss::mean_gain(
                                        s.center_freq_hz,
                                        d,
                                        !los[i],
                                    );
                                    let new_mean = pathloss::mean_gain(
                                        s.center_freq_hz,
                                        d,
                                        los[i],
                                    );
                                    block_gains[i][k] *=
                                        new_mean / old_mean;
                                }
                            }
                        }
                    }
                }
                // 3. Compute jitter: memoryless around the base f_i.
                if let (Some(js), Some(jrng)) =
                    (spec.compute_jitter.as_ref(), jit_rng.as_mut())
                {
                    for i in 0..c {
                        f_now[i] = base_f[i]
                            * (1.0
                                + jrng.uniform(-js.amplitude, js.amplitude));
                    }
                }
            }

            // 4. This round's full-roster deployment.
            let clients_now: Vec<ClientLink> = (0..c)
                .map(|i| ClientLink {
                    distance_m: roster.clients[i].distance_m,
                    f_client: f_now[i],
                    los: los[i],
                })
                .collect();
            let roster_now =
                Deployment::new(clients_now, roster.subchannels.clone());

            // 5. Channel: block-fading redraw or recomputed averages.
            match spec.redraw_period {
                Some(k) if r % k == 0 => {
                    block_gains =
                        ChannelRealization::sample(&roster_now, rng).gain;
                }
                Some(_) => {} // hold the block's gains
                None => {
                    block_gains =
                        ChannelRealization::average(&roster_now).gain;
                }
            }

            // 6. Project onto the active subset.
            let idx: Vec<usize> = (0..c).filter(|&i| active[i]).collect();
            let (dep, ch) = if idx.len() == c {
                (
                    roster_now,
                    ChannelRealization { gain: block_gains.clone() },
                )
            } else {
                let clients: Vec<ClientLink> =
                    idx.iter().map(|&i| roster_now.clients[i]).collect();
                let gain: Vec<Vec<f64>> =
                    idx.iter().map(|&i| block_gains[i].clone()).collect();
                (
                    Deployment::new(clients, roster.subchannels.clone()),
                    ChannelRealization { gain },
                )
            };
            rounds.push(ScenarioRound {
                round: r,
                dep,
                ch,
                active: idx,
                membership_changed,
            });
        }
        Ok(Scenario { net, spec, roster, rounds })
    }

    pub fn n_rounds(&self) -> usize {
        self.rounds.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Subchannel;
    use crate::scenario::spec::{ChurnSpec, ComputeJitterSpec, LosFlipSpec};

    fn net() -> NetworkConfig {
        NetworkConfig::default()
    }

    /// Hand-built roster with far (flippy) and near (stable) clients.
    fn fixed_roster() -> Deployment {
        let mk = |d, los| ClientLink { distance_m: d, f_client: 1.2e9, los };
        let clients =
            vec![mk(150.0, true), mk(10.0, true), mk(120.0, false)];
        let subchannels = (0..6)
            .map(|k| Subchannel {
                index: k,
                center_freq_hz: 28e9 + (k as f64 + 0.5) * 10e6,
                bandwidth_hz: 10e6,
            })
            .collect();
        Deployment::new(clients, subchannels)
    }

    #[test]
    fn same_seed_same_sequence() {
        let spec = ScenarioSpec {
            rounds: 12,
            redraw_period: Some(2),
            los_flip: Some(LosFlipSpec { flip_prob: 0.5 }),
            compute_jitter: Some(ComputeJitterSpec { amplitude: 0.2 }),
            churn: Some(ChurnSpec {
                drop_prob: 0.2,
                rejoin_prob: 0.5,
                min_active: 2,
            }),
        };
        let a = Scenario::generate(&net(), &spec, 0xA11CE).unwrap();
        let b = Scenario::generate(&net(), &spec, 0xA11CE).unwrap();
        assert_eq!(a.n_rounds(), b.n_rounds());
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(ra.active, rb.active);
            assert_eq!(ra.membership_changed, rb.membership_changed);
            assert_eq!(ra.dep.n_clients(), rb.dep.n_clients());
            for (ga, gb) in ra.ch.gain.iter().zip(&rb.ch.gain) {
                for (x, y) in ga.iter().zip(gb) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            for (x, y) in
                ra.dep.f_clients().iter().zip(rb.dep.f_clients())
            {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        let c = Scenario::generate(&net(), &spec, 0xB0B).unwrap();
        assert_ne!(
            a.rounds[0].ch.gain[0][0].to_bits(),
            c.rounds[0].ch.gain[0][0].to_bits()
        );
    }

    #[test]
    fn pure_fading_matches_legacy_sample_stream() {
        // The Fig. 13 parity contract: a fading-only spec consumes the
        // caller's RNG exactly like the pre-scenario per-round
        // `ChannelRealization::sample` loop.
        let n = net();
        let n_rounds = 7;
        let mut rng_legacy = Rng::new(0x13);
        let dep_legacy = Deployment::generate(&n, &mut rng_legacy);
        let legacy: Vec<ChannelRealization> = (0..n_rounds)
            .map(|_| ChannelRealization::sample(&dep_legacy, &mut rng_legacy))
            .collect();

        let mut rng = Rng::new(0x13);
        let dep = Deployment::generate(&n, &mut rng);
        let sc = Scenario::from_deployment(
            n.clone(),
            dep,
            ScenarioSpec::fading(n_rounds),
            &mut rng,
        )
        .unwrap();
        assert_eq!(sc.n_rounds(), n_rounds);
        for (r, old) in sc.rounds.iter().zip(&legacy) {
            assert!(!r.membership_changed);
            assert_eq!(r.active.len(), n.n_clients);
            for (ga, gb) in r.ch.gain.iter().zip(&old.gain) {
                for (x, y) in ga.iter().zip(gb) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
        // And both streams end in the same place.
        assert_eq!(rng.next_u64(), rng_legacy.next_u64());
    }

    #[test]
    fn static_spec_holds_average_gains() {
        let sc =
            Scenario::generate(&net(), &ScenarioSpec::static_channel(5), 9)
                .unwrap();
        let avg = ChannelRealization::average(&sc.roster);
        for r in &sc.rounds {
            assert_eq!(r.dep.n_clients(), sc.roster.n_clients());
            for (ga, gb) in r.ch.gain.iter().zip(&avg.gain) {
                for (x, y) in ga.iter().zip(gb) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            assert_eq!(
                r.dep.f_clients(),
                sc.roster.f_clients(),
                "no jitter configured"
            );
        }
    }

    #[test]
    fn block_fading_holds_within_blocks() {
        let sc = Scenario::generate(
            &net(),
            &ScenarioSpec::block_fading(9, 3),
            77,
        )
        .unwrap();
        for r in 0..9 {
            let block_start = (r / 3) * 3;
            assert_eq!(
                sc.rounds[r].ch.gain[0][0].to_bits(),
                sc.rounds[block_start].ch.gain[0][0].to_bits(),
                "round {r} left its fading block"
            );
        }
        assert_ne!(
            sc.rounds[0].ch.gain[0][0].to_bits(),
            sc.rounds[3].ch.gain[0][0].to_bits(),
            "blocks redraw"
        );
    }

    #[test]
    fn los_flips_change_states_and_average_gains() {
        let spec = ScenarioSpec {
            rounds: 60,
            redraw_period: None,
            los_flip: Some(LosFlipSpec { flip_prob: 1.0 }),
            compute_jitter: None,
            churn: None,
        };
        let mut rng = Rng::new(5);
        let sc = Scenario::from_deployment(
            net(),
            fixed_roster(),
            spec,
            &mut rng,
        )
        .unwrap();
        // The far clients (p_flip ≈ 0.87 / round) must flip at least once
        // over 60 rounds with this deterministic seed.
        let flipped = sc.rounds.iter().any(|r| {
            r.dep.clients[0].los != sc.roster.clients[0].los
                || r.dep.clients[2].los != sc.roster.clients[2].los
        });
        assert!(flipped, "no LoS flip in 60 rounds at flip_prob=1");
        // A flip moves the deterministic average gains (no fading here).
        let g0 = sc.rounds[0].ch.gain[0][0];
        assert!(sc.rounds.iter().any(|r| r.ch.gain[0][0] != g0));
    }

    #[test]
    fn los_flips_rescale_held_block_gains() {
        // Regression: with block fading (gains held between redraws) a
        // LoS flip must still move the realized gains immediately — the
        // held row keeps its shadowing realization but the pathloss
        // component follows the new state, so `ch` and `dep` never
        // disagree mid-block.
        let spec = ScenarioSpec {
            rounds: 6,
            redraw_period: Some(100), // one block for the whole scenario
            los_flip: Some(LosFlipSpec { flip_prob: 1.0 }),
            compute_jitter: None,
            churn: None,
        };
        let mut rng = Rng::new(7);
        let sc = Scenario::from_deployment(
            net(),
            fixed_roster(),
            spec,
            &mut rng,
        )
        .unwrap();
        let r0 = &sc.rounds[0];
        let mut saw_flip = false;
        for r in &sc.rounds {
            for (i, cl) in r.dep.clients.iter().enumerate() {
                let cl0 = &r0.dep.clients[i];
                let d = cl0.distance_m;
                for (k, s) in r0.dep.subchannels.iter().enumerate() {
                    // Held gain = round-0 gain × pathloss ratio of the
                    // current vs round-0 LoS state (flips compose
                    // multiplicatively, so flip-and-back cancels).
                    let ratio = crate::channel::pathloss::mean_gain(
                        s.center_freq_hz,
                        d,
                        cl.los,
                    ) / crate::channel::pathloss::mean_gain(
                        s.center_freq_hz,
                        d,
                        cl0.los,
                    );
                    let expect = r0.ch.gain[i][k] * ratio;
                    let got = r.ch.gain[i][k];
                    assert!(
                        (got - expect).abs() <= 1e-9 * expect.abs(),
                        "round {} client {i} subch {k}: {got} vs {expect}",
                        r.round
                    );
                }
                saw_flip |= cl.los != cl0.los;
            }
        }
        assert!(saw_flip, "no LoS flip occurred over 6 rounds at p=1");
    }

    #[test]
    fn jitter_stays_within_amplitude() {
        let spec = ScenarioSpec {
            rounds: 30,
            redraw_period: None,
            los_flip: None,
            compute_jitter: Some(ComputeJitterSpec { amplitude: 0.25 }),
            churn: None,
        };
        let sc = Scenario::generate(&net(), &spec, 3).unwrap();
        let base = sc.roster.f_clients().to_vec();
        let mut moved = false;
        for r in &sc.rounds {
            for (f, b) in r.dep.f_clients().iter().zip(&base) {
                let ratio = f / b;
                assert!(
                    (0.75..=1.25).contains(&ratio),
                    "jitter ratio {ratio} out of band"
                );
                if (ratio - 1.0).abs() > 1e-9 {
                    moved = true;
                }
            }
        }
        assert!(moved, "jitter never moved f");
    }

    #[test]
    fn feature_streams_are_independent() {
        // Toggling one feature must not perturb another feature's draws
        // or the fading stream: compare {fading + jitter} against
        // {fading + jitter + no-op churn} — gains and jittered compute
        // must match bit for bit (pre-fix, the chained forks shifted
        // every downstream stream when churn was enabled).
        let mk = |churn: Option<ChurnSpec>| ScenarioSpec {
            rounds: 8,
            redraw_period: Some(1),
            los_flip: None,
            compute_jitter: Some(ComputeJitterSpec { amplitude: 0.2 }),
            churn,
        };
        let a = Scenario::generate(&net(), &mk(None), 0x1D).unwrap();
        let b = Scenario::generate(
            &net(),
            &mk(Some(ChurnSpec {
                drop_prob: 0.0,
                rejoin_prob: 0.0,
                min_active: 1,
            })),
            0x1D,
        )
        .unwrap();
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(ra.active, rb.active);
            for (ga, gb) in ra.ch.gain.iter().zip(&rb.ch.gain) {
                for (x, y) in ga.iter().zip(gb) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            for (x, y) in
                ra.dep.f_clients().iter().zip(rb.dep.f_clients())
            {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn churn_respects_min_active_and_flags_changes() {
        let spec = ScenarioSpec {
            rounds: 50,
            redraw_period: Some(1),
            los_flip: None,
            compute_jitter: None,
            churn: Some(ChurnSpec {
                drop_prob: 0.3,
                rejoin_prob: 0.3,
                min_active: 2,
            }),
        };
        let sc = Scenario::generate(&net(), &spec, 21).unwrap();
        let mut prev: Vec<usize> = (0..sc.roster.n_clients()).collect();
        let mut changed_any = false;
        for r in &sc.rounds {
            assert!(r.active.len() >= 2, "fell below min_active");
            assert_eq!(r.dep.n_clients(), r.active.len());
            assert_eq!(r.ch.gain.len(), r.active.len());
            assert_eq!(r.membership_changed, r.active != prev);
            changed_any |= r.membership_changed;
            prev = r.active.clone();
        }
        assert!(changed_any, "churn never changed membership at p=0.3");
        // Projected rows match the roster client parameters.
        for r in &sc.rounds {
            for (j, &i) in r.active.iter().enumerate() {
                assert_eq!(
                    r.dep.clients[j].distance_m,
                    sc.roster.clients[i].distance_m
                );
            }
        }
    }
}
