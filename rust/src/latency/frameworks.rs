//! Per-round latency models for every SL framework the paper compares
//! (Fig. 1 / Table I): vanilla SL, SFL, PSL, EPSL, and EPSL-PT.
//!
//! - **EPSL**: eqs. 13–23 directly ([`epsl_stage_latencies`]).
//! - **PSL**: EPSL with φ = 0 (no broadcast, full unicast, full server BP).
//! - **SFL**: PSL round **plus** client-side model exchange — every client
//!   uploads its client-side model, the server FedAvg-aggregates and
//!   broadcasts the result back (Thapa et al.).
//! - **Vanilla SL**: strictly sequential — each client in turn runs the
//!   full split round with the server at C = 1, then relays the client-side
//!   model to the next client through the server.
//! - **EPSL-PT**: phased training — EPSL with φ = 1 for the first fraction
//!   of rounds, then φ = 0 (the framework drivers flip φ; per-round latency
//!   here is parameterized by the current φ).

use super::{
    epsl_stage_latencies, epsl_stage_latencies_hetero, LatencyInputs,
    StageLatencies,
};
use crate::error::{Error, Result};

/// The five frameworks of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Framework {
    VanillaSl,
    Sfl,
    Psl,
    Epsl { phi: f64 },
    /// Phased training: φ=1 early, φ=0 late. `early` marks the phase.
    EpslPt { early: bool },
}

impl Framework {
    pub fn name(&self) -> &'static str {
        match self {
            Framework::VanillaSl => "vanilla SL",
            Framework::Sfl => "SFL",
            Framework::Psl => "PSL",
            Framework::Epsl { .. } => "EPSL",
            Framework::EpslPt { .. } => "EPSL-PT",
        }
    }

    /// The effective aggregation ratio this framework runs with.
    pub fn phi(&self) -> f64 {
        match self {
            Framework::VanillaSl | Framework::Sfl | Framework::Psl => 0.0,
            Framework::Epsl { phi } => *phi,
            Framework::EpslPt { early } => {
                if *early {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Does the framework exchange client-side models each round?
    pub fn exchanges_model(&self) -> bool {
        matches!(self, Framework::Sfl)
    }

    /// Table I rows: (partial offload, parallel, model exchange,
    /// gradient-dimension reduction, raw-data access).
    pub fn capabilities(&self) -> (bool, bool, bool, bool, bool) {
        match self {
            Framework::VanillaSl => (true, false, false, false, false),
            Framework::Sfl => (true, true, true, false, false),
            Framework::Psl => (true, true, false, false, false),
            Framework::Epsl { .. } | Framework::EpslPt { .. } => {
                (true, true, false, true, false)
            }
        }
    }
}

/// Per-round latency of `fw` under the given inputs. `inp.phi` is ignored —
/// the framework defines its own φ.
pub fn round_latency(fw: Framework, inp: &LatencyInputs) -> StageLatencies {
    let mut my = inp.clone();
    my.phi = fw.phi();
    match fw {
        Framework::Epsl { .. }
        | Framework::Psl
        | Framework::EpslPt { .. } => epsl_stage_latencies(&my),
        Framework::Sfl => {
            let mut s = epsl_stage_latencies(&my);
            s.model_exchange = sfl_model_exchange(inp);
            s
        }
        Framework::VanillaSl => vanilla_sl_round(inp),
    }
}

/// Mixed-cut per-round latency: client i splits at `cuts[i]`. Only the
/// parallel frameworks (PSL / EPSL / EPSL-PT) support per-client cuts —
/// SFL's FedAvg model exchange and vanilla SL's model relay both require
/// every client-side model to have the same shape, so they are rejected
/// with a typed error. All-equal `cuts` are bit-identical to
/// [`round_latency`] at that cut (the hetero stage function delegates).
pub fn round_latency_hetero(fw: Framework, inp: &LatencyInputs,
                            cuts: &[usize]) -> Result<StageLatencies> {
    match fw {
        Framework::Epsl { .. }
        | Framework::Psl
        | Framework::EpslPt { .. } => {
            let mut my = inp.clone();
            my.phi = fw.phi();
            Ok(epsl_stage_latencies_hetero(&my, cuts))
        }
        Framework::Sfl | Framework::VanillaSl => Err(Error::Config(format!(
            "{} does not support per-client cut layers (client-side \
             models must share one shape)",
            fw.name()
        ))),
    }
}

/// SFL model-exchange components: per-client model upload seconds
/// (unicast over each client's own subchannels) and the aggregated-model
/// broadcast seconds. Exposed separately so the timeline engine can
/// overlap the uploads with the round tail; [`round_latency`] composes
/// them into the single serial term the closed form uses.
pub fn sfl_exchange_parts(inp: &LatencyInputs) -> (Vec<f64>, f64) {
    let u = inp.profile.client_model_bits(inp.cut);
    let uploads: Vec<f64> =
        inp.uplink.iter().map(|r| u / r.max(1e-9)).collect();
    let down = u / inp.broadcast.max(1e-9);
    (uploads, down)
}

/// SFL model-exchange time: slowest client-model upload (unicast over the
/// client's own subchannels) + aggregated-model broadcast.
fn sfl_model_exchange(inp: &LatencyInputs) -> f64 {
    let (uploads, down) = sfl_exchange_parts(inp);
    let up_max = uploads.iter().cloned().fold(0.0, f64::max);
    up_max + down
}

/// Vanilla SL "round": one sequential pass over all C clients (each trains
/// with the server alone on one mini-batch), with the client-side model
/// relayed to the next client via the server between turns. Reported as a
/// single [`StageLatencies`] whose fields hold the *summed* sequential
/// terms so `round_total()` stays comparable.
fn vanilla_sl_round(inp: &LatencyInputs) -> StageLatencies {
    let p = inp.profile;
    let j = inp.cut;
    let b = inp.batch as f64;
    let u = p.client_model_bits(j);
    let mut total_cf = 0.0;
    let mut total_up = 0.0;
    let mut server_fp = 0.0;
    let mut server_bp = 0.0;
    let mut total_dn = 0.0;
    let mut total_cb = 0.0;
    let mut relay = 0.0;
    let n = inp.n_clients();
    for i in 0..n {
        let fi = inp.f_clients[i];
        total_cf += b * inp.kappa_client * p.client_fp_flops(j) / fi;
        total_up +=
            b * p.psi_bits(j) * inp.uplink_comp / inp.uplink[i].max(1e-9);
        // server trains alone with this client: C = 1, φ = 0
        server_fp += b * inp.kappa_server * p.server_fp_flops(j)
            / inp.f_server;
        server_bp += (b * inp.kappa_server * p.server_bp_flops(j)
            + b * inp.kappa_server * p.last_layer_bp_flops())
            / inp.f_server;
        total_dn += b * p.chi_bits(j) / inp.downlink[i].max(1e-9);
        total_cb += b * inp.kappa_client * p.client_bp_flops(j) / fi;
        // model relay to the next client: up over i's link, down over i+1's
        if i + 1 < n {
            relay += u / inp.uplink[i].max(1e-9)
                + u / inp.downlink[i + 1].max(1e-9);
        }
    }
    StageLatencies {
        client_fp: vec![total_cf],
        uplink: vec![total_up],
        server_fp,
        server_bp,
        broadcast: 0.0,
        downlink: vec![total_dn],
        client_bp: vec![total_cb],
        model_exchange: relay,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::resnet18;
    use crate::profile::NetworkProfile;

    fn inputs<'a>(p: &'a NetworkProfile, f: &'a [f64], up: &'a [f64],
                  dn: &'a [f64]) -> LatencyInputs<'a> {
        LatencyInputs {
            profile: p,
            cut: 2,
            batch: 64,
            phi: 0.5,
            f_server: 5e9,
            kappa_server: 1.0 / 32.0,
            kappa_client: 1.0 / 16.0,
            f_clients: f,
            uplink: up,
            downlink: dn,
            broadcast: 2e8,
            uplink_comp: 1.0,
        }
    }

    #[test]
    fn paper_ordering_epsl_fastest_vanilla_slowest() {
        // Fig. 4b / Fig. 9: EPSL < PSL < SFL < vanilla SL per round.
        let p = resnet18::profile();
        let f = [1e9, 1.2e9, 1.4e9, 1.6e9, 1.1e9];
        let up = [1.5e8; 5];
        let dn = [1.5e8; 5];
        let inp = inputs(&p, &f, &up, &dn);
        let epsl =
            round_latency(Framework::Epsl { phi: 0.5 }, &inp).round_total();
        let psl = round_latency(Framework::Psl, &inp).round_total();
        let sfl = round_latency(Framework::Sfl, &inp).round_total();
        let vsl = round_latency(Framework::VanillaSl, &inp).round_total();
        assert!(epsl < psl, "EPSL {epsl} !< PSL {psl}");
        assert!(psl < sfl, "PSL {psl} !< SFL {sfl}");
        assert!(sfl < vsl, "SFL {sfl} !< vanilla {vsl}");
    }

    #[test]
    fn psl_equals_epsl_phi0() {
        let p = resnet18::profile();
        let f = [1e9; 4];
        let up = [1e8; 4];
        let dn = [1e8; 4];
        let inp = inputs(&p, &f, &up, &dn);
        let a = round_latency(Framework::Psl, &inp).round_total();
        let b =
            round_latency(Framework::Epsl { phi: 0.0 }, &inp).round_total();
        assert!((a - b).abs() < 1e-15);
    }

    #[test]
    fn sfl_adds_model_exchange_over_psl() {
        let p = resnet18::profile();
        let f = [1e9; 3];
        let up = [1e8; 3];
        let dn = [1e8; 3];
        let inp = inputs(&p, &f, &up, &dn);
        let psl = round_latency(Framework::Psl, &inp);
        let sfl = round_latency(Framework::Sfl, &inp);
        assert_eq!(psl.model_exchange, 0.0);
        assert!(sfl.model_exchange > 0.0);
        assert!(
            (sfl.round_total() - psl.round_total() - sfl.model_exchange)
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn vanilla_scales_linearly_with_clients() {
        let p = resnet18::profile();
        let f2 = [1e9; 2];
        let f4 = [1e9; 4];
        let up2 = [1e8; 2];
        let up4 = [1e8; 4];
        let dn2 = [1e8; 2];
        let dn4 = [1e8; 4];
        let t2 = round_latency(Framework::VanillaSl, &inputs(&p, &f2, &up2, &dn2))
            .round_total();
        let t4 = round_latency(Framework::VanillaSl, &inputs(&p, &f4, &up4, &dn4))
            .round_total();
        assert!(t4 > 1.8 * t2, "t4={t4} vs t2={t2}");
    }

    #[test]
    fn epsl_pt_flips_phi() {
        assert_eq!(Framework::EpslPt { early: true }.phi(), 1.0);
        assert_eq!(Framework::EpslPt { early: false }.phi(), 0.0);
    }

    #[test]
    fn capability_matrix_matches_table1() {
        // (offload, parallel, model exchange, dim reduction, raw access)
        assert_eq!(
            Framework::VanillaSl.capabilities(),
            (true, false, false, false, false)
        );
        assert_eq!(
            Framework::Sfl.capabilities(),
            (true, true, true, false, false)
        );
        assert_eq!(
            Framework::Psl.capabilities(),
            (true, true, false, false, false)
        );
        assert_eq!(
            Framework::Epsl { phi: 0.5 }.capabilities(),
            (true, true, false, true, false)
        );
    }

    #[test]
    fn higher_phi_strictly_faster_round() {
        let p = resnet18::profile();
        let f = [1e9; 5];
        let up = [1e8; 5];
        let dn = [1e8; 5];
        let inp = inputs(&p, &f, &up, &dn);
        let mut last = f64::INFINITY;
        for phi in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let t = round_latency(Framework::Epsl { phi }, &inp).round_total();
            assert!(t < last, "phi={phi}: {t} !< {last}");
            last = t;
        }
    }
}
