//! Per-round training-latency model — paper §V-A, eqs. (13)–(23).
//!
//! Seven stages per EPSL round (Fig. 5):
//! 1. client-side FP (eq. 13) — parallel across clients
//! 2. smashed-data uplink (eq. 15)
//! 3. server-side FP over C·b samples (eq. 16)
//! 4. server-side BP with last-layer aggregation (eq. 17)
//! 5. aggregated-gradient broadcast (eq. 19)
//! 6. unaggregated-gradient unicast (eq. 21)
//! 7. client-side BP (eq. 22)
//!
//! Round total (eq. 23):
//! `max_i(T_i^F + T_i^U) + T_s^F + T_s^B + T^B + max_i(T_i^D + T_i^B)`.

pub mod frameworks;

use crate::profile::NetworkProfile;

/// Everything the stage-latency formulas consume for one configuration.
#[derive(Debug, Clone)]
pub struct LatencyInputs<'a> {
    pub profile: &'a NetworkProfile,
    /// Cut layer j (1-based; must be a cut candidate).
    pub cut: usize,
    /// Mini-batch size b per client.
    pub batch: usize,
    /// Aggregation ratio φ ∈ [0, 1].
    pub phi: f64,
    /// Server compute f_s (cycles/s) and intensity κ_s (cycles/FLOP).
    pub f_server: f64,
    pub kappa_server: f64,
    /// Client compute intensity κ (cycles/FLOP), equal across clients.
    pub kappa_client: f64,
    /// Per-client compute f_i (cycles/s).
    pub f_clients: &'a [f64],
    /// Per-client uplink rates R_i^U (bits/s) — eq. 14.
    pub uplink: &'a [f64],
    /// Per-client downlink rates R_i^D (bits/s) — eq. 20.
    pub downlink: &'a [f64],
    /// Broadcast rate R^B (bits/s) — eq. 18.
    pub broadcast: f64,
    /// Uplink activation-payload compression factor in (0, 1] — scales
    /// the eq. 15 payload `b·ψ_j` (1.0 = raw f32, bit-identical to the
    /// uncompressed model; 0.5 ≈ f16, 0.25 ≈ int8).
    pub uplink_comp: f64,
}

impl<'a> LatencyInputs<'a> {
    pub fn n_clients(&self) -> usize {
        self.f_clients.len()
    }

    /// ⌈φb⌉.
    pub fn aggregated_count(&self) -> usize {
        (self.phi * self.batch as f64).ceil() as usize
    }
}

/// Per-stage latencies of one round (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct StageLatencies {
    /// T_i^F — client FP (eq. 13).
    pub client_fp: Vec<f64>,
    /// T_i^U — smashed uplink (eq. 15).
    pub uplink: Vec<f64>,
    /// T_s^F — server FP (eq. 16).
    pub server_fp: f64,
    /// T_s^B — server BP (eq. 17).
    pub server_bp: f64,
    /// T^B — aggregated-gradient broadcast (eq. 19).
    pub broadcast: f64,
    /// T_i^D — unaggregated-gradient unicast (eq. 21).
    pub downlink: Vec<f64>,
    /// T_i^B — client BP (eq. 22).
    pub client_bp: Vec<f64>,
    /// Extra serial term (model exchange for SFL, relay for vanilla SL).
    pub model_exchange: f64,
}

impl StageLatencies {
    /// Eq. (23) round total (+ any model-exchange term).
    pub fn round_total(&self) -> f64 {
        self.uplink_phase_max()
            + self.server_fp
            + self.server_bp
            + self.broadcast
            + self.downlink_phase_max()
            + self.model_exchange
    }

    /// `max_i (T_i^F + T_i^U)` — the uplink-side straggler.
    pub fn uplink_phase_max(&self) -> f64 {
        self.client_fp
            .iter()
            .zip(&self.uplink)
            .map(|(f, u)| f + u)
            .fold(0.0, f64::max)
    }

    /// `max_i (T_i^D + T_i^B)` — the downlink-side straggler.
    pub fn downlink_phase_max(&self) -> f64 {
        self.downlink
            .iter()
            .zip(&self.client_bp)
            .map(|(d, b)| d + b)
            .fold(0.0, f64::max)
    }

    /// Index of the uplink-phase straggler.
    pub fn uplink_straggler(&self) -> usize {
        let mut best = 0;
        let mut bestv = f64::NEG_INFINITY;
        for (i, (f, u)) in self.client_fp.iter().zip(&self.uplink).enumerate()
        {
            if f + u > bestv {
                bestv = f + u;
                best = i;
            }
        }
        best
    }

    /// Total communication seconds (uplink max + broadcast + downlink max
    /// + exchange) — for the paper's comm/compute split discussion.
    pub fn comm_seconds(&self) -> f64 {
        let umax = self.uplink.iter().cloned().fold(0.0, f64::max);
        let dmax = self.downlink.iter().cloned().fold(0.0, f64::max);
        umax + self.broadcast + dmax + self.model_exchange
    }

    /// Total computation seconds (client FP straggler + server FP/BP +
    /// client BP straggler) — the complement of [`comm_seconds`]. Because
    /// the round total pairs each client's compute with its own links
    /// (`max_i(T_i^F + T_i^U)`), the split satisfies
    /// `comm_seconds + compute_seconds ≥ round_total`, with equality when
    /// the per-client stage maxima are achieved by the same client (e.g.
    /// homogeneous clients, or C = 1).
    pub fn compute_seconds(&self) -> f64 {
        let fmax = self.client_fp.iter().cloned().fold(0.0, f64::max);
        let bmax = self.client_bp.iter().cloned().fold(0.0, f64::max);
        fmax + self.server_fp + self.server_bp + bmax
    }
}

/// Compute the seven EPSL stage latencies (eqs. 13, 15–17, 19, 21–22).
pub fn epsl_stage_latencies(inp: &LatencyInputs) -> StageLatencies {
    let p = inp.profile;
    let j = inp.cut;
    let b = inp.batch as f64;
    let c = inp.n_clients() as f64;
    let m = inp.aggregated_count() as f64; // ⌈φb⌉

    // eq. 13: T_i^F = b κ_i Φ_c^F / f_i
    let phi_cf = p.client_fp_flops(j);
    let client_fp: Vec<f64> = inp
        .f_clients
        .iter()
        .map(|fi| b * inp.kappa_client * phi_cf / fi)
        .collect();

    // eq. 15: T_i^U = b ψ_j γ / R_i^U (γ = uplink compression factor;
    // γ = 1 leaves the product bit-identical to the uncompressed form).
    let psi = p.psi_bits(j);
    let uplink: Vec<f64> = inp
        .uplink
        .iter()
        .map(|r| b * psi * inp.uplink_comp / r.max(1e-9))
        .collect();

    // eq. 16: T_s^F = C b κ_s Φ_s^F / f_s
    let server_fp =
        c * b * inp.kappa_server * p.server_fp_flops(j) / inp.f_server;

    // eq. 17: T_s^B = [(⌈φb⌉ + C(b−⌈φb⌉)) κ_s Φ_s^B + C b κ_s Φ_s^L] / f_s
    let eff_samples = m + c * (b - m);
    let server_bp = (eff_samples * inp.kappa_server * p.server_bp_flops(j)
        + c * b * inp.kappa_server * p.last_layer_bp_flops())
        / inp.f_server;

    // eq. 19: T^B = ⌈φb⌉ χ_j / R^B
    let chi = p.chi_bits(j);
    let broadcast = m * chi / inp.broadcast.max(1e-9);

    // eq. 21: T_i^D = (b − ⌈φb⌉) χ_j / R_i^D
    let downlink: Vec<f64> = inp
        .downlink
        .iter()
        .map(|r| (b - m) * chi / r.max(1e-9))
        .collect();

    // eq. 22: T_i^B = b κ_i Φ_c^B / f_i
    let phi_cb = p.client_bp_flops(j);
    let client_bp: Vec<f64> = inp
        .f_clients
        .iter()
        .map(|fi| b * inp.kappa_client * phi_cb / fi)
        .collect();

    StageLatencies {
        client_fp,
        uplink,
        server_fp,
        server_bp,
        broadcast,
        downlink,
        client_bp,
        model_exchange: 0.0,
    }
}

/// Mixed-cut extension of the seven EPSL stages: client i splits at
/// `cuts[i]` (len must equal `inp.n_clients()`; `inp.cut` is ignored).
///
/// Client-side terms (eqs. 13, 15, 21, 22) use each client's own cut.
/// Server-side terms sum over cut groups in ascending cut order: a group
/// g of c_g clients at cut j contributes
///
/// - FP: `c_g · (b κ_s Φ_s^F(j) / f_s)` — eq. 16 restricted to the group
/// - BP: `(⌈φb⌉ + c_g(b−⌈φb⌉)) · (κ_s Φ_s^B(j) / f_s) +
///        c_g · (b κ_s Φ_s^L / f_s)` — eq. 17 per group (the aggregated
///   rows back-propagate once per distinct suffix, since suffixes at
///   different cuts are distinct parameter sets)
/// - broadcast: `⌈φb⌉ χ_j / R^B` — eq. 19 per distinct cut (each group
///   receives the aggregated gradient at its own boundary)
///
/// An all-equal `cuts` vector delegates to [`epsl_stage_latencies`], so
/// it is bit-identical to the uniform closed form. The per-cut "unit"
/// terms above (parenthesized) are the canonical association; the
/// evaluator fast path replicates them operation for operation.
pub fn epsl_stage_latencies_hetero(
    inp: &LatencyInputs,
    cuts: &[usize],
) -> StageLatencies {
    debug_assert_eq!(cuts.len(), inp.n_clients());
    if let Some((first, rest)) = cuts.split_first() {
        if rest.iter().all(|c| c == first) {
            let uni = LatencyInputs { cut: *first, ..inp.clone() };
            return epsl_stage_latencies(&uni);
        }
    }
    let p = inp.profile;
    let b = inp.batch as f64;
    let m = inp.aggregated_count() as f64; // ⌈φb⌉

    // eqs. 13/15/21/22 with per-client cuts.
    let client_fp: Vec<f64> = inp
        .f_clients
        .iter()
        .zip(cuts)
        .map(|(fi, &j)| b * inp.kappa_client * p.client_fp_flops(j) / fi)
        .collect();
    let uplink: Vec<f64> = inp
        .uplink
        .iter()
        .zip(cuts)
        .map(|(r, &j)| b * p.psi_bits(j) * inp.uplink_comp / r.max(1e-9))
        .collect();
    let downlink: Vec<f64> = inp
        .downlink
        .iter()
        .zip(cuts)
        .map(|(r, &j)| (b - m) * p.chi_bits(j) / r.max(1e-9))
        .collect();
    let client_bp: Vec<f64> = inp
        .f_clients
        .iter()
        .zip(cuts)
        .map(|(fi, &j)| b * inp.kappa_client * p.client_bp_flops(j) / fi)
        .collect();

    // Server terms grouped by distinct cut, ascending.
    let mut distinct: Vec<usize> = cuts.to_vec();
    distinct.sort_unstable();
    distinct.dedup();
    let mut server_fp = 0.0;
    let mut server_bp = 0.0;
    let mut broadcast = 0.0;
    for &j in &distinct {
        let c_g = cuts.iter().filter(|&&c| c == j).count() as f64;
        let sfp1 = b * inp.kappa_server * p.server_fp_flops(j)
            / inp.f_server;
        let sbp_unit =
            inp.kappa_server * p.server_bp_flops(j) / inp.f_server;
        let sll_unit = b * inp.kappa_server * p.last_layer_bp_flops()
            / inp.f_server;
        let eff_g = m + c_g * (b - m);
        server_fp += c_g * sfp1;
        server_bp += eff_g * sbp_unit + c_g * sll_unit;
        broadcast += m * p.chi_bits(j) / inp.broadcast.max(1e-9);
    }

    StageLatencies {
        client_fp,
        uplink,
        server_fp,
        server_bp,
        broadcast,
        downlink,
        client_bp,
        model_exchange: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::resnet18;

    fn inputs<'a>(p: &'a NetworkProfile, f: &'a [f64], up: &'a [f64],
                  dn: &'a [f64], phi: f64) -> LatencyInputs<'a> {
        LatencyInputs {
            profile: p,
            cut: 3,
            batch: 64,
            phi,
            f_server: 5e9,
            kappa_server: 1.0 / 32.0,
            kappa_client: 1.0 / 16.0,
            f_clients: f,
            uplink: up,
            downlink: dn,
            broadcast: 2e8,
            uplink_comp: 1.0,
        }
    }

    #[test]
    fn stage13_formula() {
        let p = resnet18::profile();
        let f = [1e9, 2e9];
        let up = [1e8, 1e8];
        let dn = [1e8, 1e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        let s = epsl_stage_latencies(&inp);
        // T_0^F = 64 * (1/16) * rho_3 / 1e9 ; faster client exactly half.
        let expect = 64.0 * (1.0 / 16.0) * p.rho(3) / 1e9;
        assert!((s.client_fp[0] - expect).abs() / expect < 1e-12);
        assert!((s.client_fp[1] - expect / 2.0).abs() / expect < 1e-12);
    }

    #[test]
    fn phi_zero_kills_broadcast_phi_one_kills_unicast() {
        let p = resnet18::profile();
        let f = [1e9; 3];
        let up = [1e8; 3];
        let dn = [1e8; 3];
        let s0 = epsl_stage_latencies(&inputs(&p, &f, &up, &dn, 0.0));
        assert_eq!(s0.broadcast, 0.0);
        assert!(s0.downlink[0] > 0.0);
        let s1 = epsl_stage_latencies(&inputs(&p, &f, &up, &dn, 1.0));
        assert!(s1.broadcast > 0.0);
        assert_eq!(s1.downlink[0], 0.0);
    }

    #[test]
    fn higher_phi_less_server_bp() {
        // eq. 17: effective samples shrink from C·b (φ=0) to
        // ⌈φb⌉ + C(b−⌈φb⌉); last-layer term constant.
        let p = resnet18::profile();
        let f = [1e9; 5];
        let up = [1e8; 5];
        let dn = [1e8; 5];
        let s0 = epsl_stage_latencies(&inputs(&p, &f, &up, &dn, 0.0));
        let s5 = epsl_stage_latencies(&inputs(&p, &f, &up, &dn, 0.5));
        let s1 = epsl_stage_latencies(&inputs(&p, &f, &up, &dn, 1.0));
        assert!(s5.server_bp < s0.server_bp);
        assert!(s1.server_bp < s5.server_bp);
    }

    #[test]
    fn round_total_is_eq23() {
        let p = resnet18::profile();
        let f = [1e9, 1.5e9];
        let up = [5e7, 2e8];
        let dn = [5e7, 2e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        let s = epsl_stage_latencies(&inp);
        let manual = s
            .client_fp
            .iter()
            .zip(&s.uplink)
            .map(|(a, b)| a + b)
            .fold(0.0, f64::max)
            + s.server_fp
            + s.server_bp
            + s.broadcast
            + s.downlink
                .iter()
                .zip(&s.client_bp)
                .map(|(a, b)| a + b)
                .fold(0.0, f64::max);
        assert!((s.round_total() - manual).abs() < 1e-15);
    }

    #[test]
    fn straggler_is_slowest_client() {
        let p = resnet18::profile();
        let f = [2e9, 1e9, 2e9]; // client 1 slowest compute
        let up = [2e8; 3];
        let dn = [2e8; 3];
        let s = epsl_stage_latencies(&inputs(&p, &f, &up, &dn, 0.5));
        assert_eq!(s.uplink_straggler(), 1);
    }

    #[test]
    fn hetero_all_equal_bitwise_matches_uniform() {
        let p = resnet18::profile();
        let f = [1e9, 1.7e9, 2.2e9];
        let up = [5e7, 1e8, 2e8];
        let dn = [6e7, 9e7, 3e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        for j in [1usize, 4, 10, 16] {
            let uni =
                epsl_stage_latencies(&LatencyInputs { cut: j, ..inp.clone() });
            let het = epsl_stage_latencies_hetero(&inp, &[j, j, j]);
            assert_eq!(uni, het, "cut {j}");
            assert_eq!(
                uni.round_total().to_bits(),
                het.round_total().to_bits()
            );
        }
    }

    #[test]
    fn hetero_mixed_matches_manual_group_sums() {
        let p = resnet18::profile();
        let f = [1e9, 2e9, 1.5e9];
        let up = [1e8, 1e8, 2e8];
        let dn = [1e8, 2e8, 1e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        let cuts = [4usize, 1, 4];
        let s = epsl_stage_latencies_hetero(&inp, &cuts);
        let b = 64.0;
        let m = inp.aggregated_count() as f64;
        // Per-client terms use each client's own cut.
        for i in 0..3 {
            let j = cuts[i];
            let fp = b * inp.kappa_client * p.client_fp_flops(j) / f[i];
            assert_eq!(s.client_fp[i].to_bits(), fp.to_bits(), "fp {i}");
            let ul = b * p.psi_bits(j) / up[i];
            assert_eq!(s.uplink[i].to_bits(), ul.to_bits(), "ul {i}");
            let dl = (b - m) * p.chi_bits(j) / dn[i];
            assert_eq!(s.downlink[i].to_bits(), dl.to_bits(), "dl {i}");
        }
        // Server FP: group {1}×1 + group {4}×2, ascending cut order.
        let sfp1 = |j: usize| {
            b * inp.kappa_server * p.server_fp_flops(j) / inp.f_server
        };
        let expect_fp = 1.0 * sfp1(1) + 2.0 * sfp1(4);
        assert_eq!(s.server_fp.to_bits(), expect_fp.to_bits());
        // Server BP: per-group eq. 17.
        let bp_g = |j: usize, c_g: f64| {
            let sbp_unit =
                inp.kappa_server * p.server_bp_flops(j) / inp.f_server;
            let sll_unit = b * inp.kappa_server * p.last_layer_bp_flops()
                / inp.f_server;
            (m + c_g * (b - m)) * sbp_unit + c_g * sll_unit
        };
        let expect_bp = bp_g(1, 1.0) + bp_g(4, 2.0);
        assert_eq!(s.server_bp.to_bits(), expect_bp.to_bits());
        // Broadcast: one eq.-19 term per distinct cut.
        let expect_bc =
            m * p.chi_bits(1) / 2e8 + m * p.chi_bits(4) / 2e8;
        assert_eq!(s.broadcast.to_bits(), expect_bc.to_bits());
    }

    #[test]
    fn uplink_compression_scales_only_the_uplink_stage() {
        let p = resnet18::profile();
        let f = [1e9, 1.5e9];
        let up = [5e7, 2e8];
        let dn = [5e7, 2e8];
        let raw = inputs(&p, &f, &up, &dn, 0.5);
        let a = epsl_stage_latencies(&raw);
        let half =
            LatencyInputs { uplink_comp: 0.5, ..raw.clone() };
        let b = epsl_stage_latencies(&half);
        for i in 0..2 {
            // γ scales the eq. 15 payload linearly...
            assert!((b.uplink[i] - 0.5 * a.uplink[i]).abs()
                        < 1e-15 * a.uplink[i].max(1.0),
                    "uplink {i}");
        }
        // ...and touches nothing else.
        assert_eq!(a.client_fp, b.client_fp);
        assert_eq!(a.server_fp.to_bits(), b.server_fp.to_bits());
        assert_eq!(a.server_bp.to_bits(), b.server_bp.to_bits());
        assert_eq!(a.broadcast.to_bits(), b.broadcast.to_bits());
        assert_eq!(a.downlink, b.downlink);
        assert_eq!(a.client_bp, b.client_bp);
        // γ = 1 is bit-identical (x * 1.0 is exact), and the hetero path
        // applies the same factor per client.
        let one = LatencyInputs { uplink_comp: 1.0, ..raw.clone() };
        let s1 = epsl_stage_latencies(&one);
        assert_eq!(a.uplink[0].to_bits(), s1.uplink[0].to_bits());
        let het = epsl_stage_latencies_hetero(&half, &[3, 1]);
        let expect = 64.0 * p.psi_bits(1) * 0.5 / up[1];
        assert_eq!(het.uplink[1].to_bits(), expect.to_bits());
    }

    #[test]
    fn faster_server_lowers_server_terms_only() {
        let p = resnet18::profile();
        let f = [1e9; 2];
        let up = [1e8; 2];
        let dn = [1e8; 2];
        let mut inp = inputs(&p, &f, &up, &dn, 0.5);
        let a = epsl_stage_latencies(&inp);
        inp.f_server = 10e9;
        let b = epsl_stage_latencies(&inp);
        assert!(b.server_fp < a.server_fp);
        assert!(b.server_bp < a.server_bp);
        assert_eq!(a.client_fp, b.client_fp);
        assert_eq!(a.uplink, b.uplink);
    }
}
