//! fig_hetero_cut — per-client cut refinement vs the uniform optimum
//! under growing compute heterogeneity (repo extension; no paper
//! analogue — the paper's Alg. 3 decision space is one cut for the whole
//! cohort).
//!
//! Each cell draws a Table-III deployment, then pulls client compute
//! toward a bimodal slow/fast split by a `spread` factor (0 = the
//! nominal draw, 1 = alternating 0.2/4 GHz extremes), and solves both
//! ways: the uniform BCD (Alg. 3) and the per-client refinement on top
//! of it ([`hetero::solve`]). Two hard gates ride on the figure:
//!
//! * every cell must satisfy `hetero ≤ uniform` (the refinement's
//!   dominance guarantee) — a violation is an error, not a silent row;
//! * at the strongest spread at least one seed must show a *strict*
//!   gain, so the figure can never silently degenerate into a flat line.

use crate::channel::{ChannelRealization, Deployment};
use crate::config::NetworkConfig;
use crate::error::{Error, Result};
use crate::optim::{hetero, Problem};
use crate::profile::resnet18;
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::{LinePlot, Table};

use super::Ctx;

/// One (spread × seed) cell.
#[derive(Debug, Clone)]
struct HeteroCell {
    net: NetworkConfig,
    /// Pull toward the bimodal slow/fast compute split: 0 = nominal
    /// Table-III draw, 1 = alternating 0.2 / 4 GHz extremes.
    spread: f64,
    dep_seed: u64,
    batch: usize,
    phi: f64,
}

/// One solved cell.
#[derive(Debug, Clone)]
struct HeteroRow {
    uniform_obj: f64,
    hetero_obj: f64,
    improved: bool,
    uniform_cut: usize,
    cut_label: String,
}

/// Solve one cell both ways; the dominance gate is checked here so a
/// violating cell fails the whole figure loudly.
fn eval_cell(cell: &HeteroCell) -> Result<HeteroRow> {
    let profile = resnet18::profile_static();
    let mut rng = Rng::new(cell.dep_seed);
    let mut dep = Deployment::generate(&cell.net, &mut rng);
    let (slow, fast) = (2e8, 4e9);
    for (i, cl) in dep.clients.iter_mut().enumerate() {
        let target = if i % 2 == 0 { slow } else { fast };
        cl.f_client =
            (1.0 - cell.spread) * cl.f_client + cell.spread * target;
    }
    dep.refresh_f_clients();
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cell.net,
        profile,
        dep: &dep,
        ch: &ch,
        batch: cell.batch,
        phi: cell.phi,
    };
    let res = hetero::solve(&prob, hetero::HeteroOptions::default())?;
    if !(res.objective <= res.uniform_objective)
        || !res.objective.is_finite()
    {
        return Err(Error::Runtime(format!(
            "hetero dominance violated: {} > uniform {} (spread {}, \
             seed {})",
            res.objective, res.uniform_objective, cell.spread,
            cell.dep_seed
        )));
    }
    Ok(HeteroRow {
        uniform_obj: res.uniform_objective,
        hetero_obj: res.objective,
        improved: res.improved,
        uniform_cut: res.uniform_cut,
        cut_label: res.decision.cut.label(),
    })
}

/// fig_hetero_cut — what does a per-client cut vector buy, as device
/// compute grows more heterogeneous?
pub fn fig_hetero_cut(ctx: &mut Ctx) -> Result<()> {
    let spreads: Vec<f64> = if ctx.quick {
        vec![0.0, 0.6, 0.9]
    } else {
        vec![0.0, 0.3, 0.6, 0.9]
    };
    let seeds: u64 = if ctx.quick { 2 } else { 5 };

    let mut cells = Vec::new();
    for &spread in &spreads {
        for s in 0..seeds {
            cells.push(HeteroCell {
                net: ctx.cfg.net.clone(),
                spread,
                dep_seed: 0xC47 + s,
                batch: ctx.cfg.train.batch,
                phi: ctx.cfg.train.phi,
            });
        }
    }
    let outs = par::parallel_map(&cells, par::max_threads(), |_, cell| {
        eval_cell(cell)
    });
    let mut rows = Vec::with_capacity(outs.len());
    for o in outs {
        rows.push(o?);
    }

    let mut t = Table::new("fig_hetero_cut").header(&[
        "spread", "uniform (s)", "hetero (s)", "gain (%)", "improved",
        "example assignment",
    ]);
    let mut plot = LinePlot::new(
        "fig_hetero_cut: per-client cut gain vs compute heterogeneity",
        "compute spread",
        "gain (%)",
    );
    let mut pts = Vec::new();
    let mut chunks = rows.chunks(seeds as usize);
    let mut max_spread_improved = 0usize;
    for &spread in &spreads {
        let chunk = chunks
            // audit:allow(R1, "the solve fan-out produced exactly one chunk per spread value, in this same order")
            .next().expect("fig_hetero_cut cell grid mismatch");
        let uni: Vec<f64> = chunk.iter().map(|r| r.uniform_obj).collect();
        let het: Vec<f64> = chunk.iter().map(|r| r.hetero_obj).collect();
        let (mu, mh) = (mean(&uni), mean(&het));
        let gain = 100.0 * (1.0 - mh / mu);
        let improved = chunk.iter().filter(|r| r.improved).count();
        // audit:allow(R1, "spreads is a fixed non-empty literal grid")
        if spread == *spreads.last().unwrap() {
            max_spread_improved = improved;
        }
        // A mixed example when one exists, the uniform label otherwise.
        let example = chunk
            .iter()
            .find(|r| r.improved)
            .map(|r| r.cut_label.clone())
            .unwrap_or_else(|| chunk[0].uniform_cut.to_string());
        pts.push((spread, gain));
        t.row(&[
            format!("{spread:.1}"),
            format!("{mu:.3}"),
            format!("{mh:.3}"),
            format!("{gain:.2}"),
            format!("{improved}/{}", chunk.len()),
            example,
        ]);
    }
    if max_spread_improved == 0 {
        return Err(Error::Runtime(
            "fig_hetero_cut: no strict hetero gain at the strongest \
             compute spread — the refinement has degenerated"
                .into(),
        ));
    }
    plot.series("hetero gain", &pts);
    println!("{}", plot.render());
    println!("{}", t.render());
    ctx.save("fig_hetero_cut.csv", &t.to_csv())?;
    ctx.save("fig_hetero_cut.txt", &plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(spread: f64, seed: u64) -> HeteroCell {
        HeteroCell {
            net: NetworkConfig::default(),
            spread,
            dep_seed: seed,
            batch: 64,
            phi: 0.5,
        }
    }

    #[test]
    fn cell_eval_is_deterministic_and_dominant() {
        let a = eval_cell(&cell(0.6, 0xC47)).unwrap();
        let b = eval_cell(&cell(0.6, 0xC47)).unwrap();
        assert_eq!(a.uniform_obj.to_bits(), b.uniform_obj.to_bits());
        assert_eq!(a.hetero_obj.to_bits(), b.hetero_obj.to_bits());
        assert_eq!(a.cut_label, b.cut_label);
        assert!(a.hetero_obj <= a.uniform_obj);
    }

    #[test]
    fn full_spread_gains_strictly() {
        // At spread 1 the deployment is the alternating 0.2 / 4 GHz
        // extreme split — the same regime the hetero solver's own
        // strict-gain test covers; the figure cell must agree.
        let r = eval_cell(&cell(1.0, 0xC47)).unwrap();
        assert!(r.improved, "no strict gain at full compute spread");
        assert!(r.hetero_obj < r.uniform_obj);
        assert!(r.cut_label.contains('-'), "label: {}", r.cut_label);
    }

    #[test]
    fn zero_spread_keeps_nominal_draw_dominance() {
        let r = eval_cell(&cell(0.0, 7)).unwrap();
        assert!(r.hetero_obj <= r.uniform_obj);
        if !r.improved {
            assert_eq!(
                r.hetero_obj.to_bits(),
                r.uniform_obj.to_bits()
            );
        }
    }
}
