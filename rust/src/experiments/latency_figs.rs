//! Latency experiments: Figs. 9–13.
//!
//! Figs. 11–13 are pure resource-management experiments over the §V model
//! (as in the paper). Figs. 9–10 combine *measured* rounds-to-target from
//! real training runs with the per-round latency model swept over C / D —
//! the paper's own latency numbers likewise come from the analytical model
//! fed by Table IV; see EXPERIMENTS.md for the documented approximation
//! (rounds-to-target measured at the anchor C, per-round latency swept).
//!
//! Every experiment grid here is *embarrassingly parallel*: each cell draws
//! its own deployment from a cell-local seed and solves independently. The
//! grids are therefore fanned across cores through [`super::sweep`] — the
//! outputs are bit-identical to the serial path for any thread count
//! (`EPSL_THREADS=1` forces serial).

use crate::channel::Deployment;
use crate::config::NetworkConfig;
use crate::error::Result;
use crate::latency::frameworks::Framework;
use crate::optim::baselines::Scheme;
use crate::optim::bcd;
use crate::profile::resnet18;
use crate::scenario::{
    self, ReoptPolicy, RunOptions, Scenario, ScenarioCell, ScenarioSpec,
};
use crate::timeline::Mode;
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::{LinePlot, Table};

use super::accuracy::curve_run;
use super::sweep::{self, FrameworkCell, SchemeCell};
use super::Ctx;

/// Build the Figs. 9–10 per-round latency cells for one client count:
/// `seeds` deployment draws per framework.
fn framework_cells(ctx: &Ctx, fws: &[Framework], n_clients: usize,
                   seeds: u64, out: &mut Vec<FrameworkCell>) {
    let net = ctx.cfg.net.clone().with_clients(n_clients);
    for &fw in fws {
        for s in 0..seeds {
            out.push(FrameworkCell {
                net: net.clone(),
                fw,
                dep_seed: 0xF16_0000 + s,
                batch: ctx.cfg.train.batch,
            });
        }
    }
}

/// Fig. 9 — total training latency to reach target accuracy vs C.
///
/// Rounds-to-target are *measured* by training at the anchor client count
/// (C=5); the per-round latency is swept over C with the §V model. The
/// paper's qualitative shape: vanilla SL grows with C, parallel schemes
/// shrink, EPSL lowest.
pub fn fig9(ctx: &mut Ctx) -> Result<()> {
    let rounds = if ctx.quick { 250 } else { 400 };
    let dataset = if ctx.quick { 1500 } else { 8000 };
    let target = 0.75;
    let sweep_c: Vec<usize> =
        if ctx.quick { vec![2, 5, 10, 20] } else { vec![2, 5, 10, 15, 20] };
    let frameworks = super::accuracy::curve_frameworks();

    // Measured rounds-to-target at the anchor C = 5.
    let mut rounds_to: Vec<(String, Framework, f64)> = Vec::new();
    for (name, fw) in &frameworks {
        if matches!(fw, Framework::Epsl { phi } if *phi == 1.0) {
            continue; // φ=1 may not reach the target (paper Table V)
        }
        let run = curve_run(ctx, "ham", true, name, *fw, 5, rounds, dataset)?;
        let r2t = run
            .rounds_to_accuracy(target)
            .unwrap_or(rounds)
            .max(1) as f64;
        println!("  {name}: rounds to {target:.0}% = {r2t}");
        rounds_to.push((name.clone(), *fw, r2t));
    }

    // Fan the full (C × framework × seed) per-round latency grid across
    // cores in one batch.
    let seeds_per = 3u64;
    let fws: Vec<Framework> = rounds_to.iter().map(|(_, fw, _)| *fw).collect();
    let mut cells = Vec::new();
    for &c in &sweep_c {
        framework_cells(ctx, &fws, c, seeds_per, &mut cells);
    }
    let outs = sweep::run_framework_cells(
        resnet18::profile_static(),
        &cells,
        par::max_threads(),
    );

    let mut plot = LinePlot::new(
        "Fig 9: total latency to target accuracy vs #clients",
        "clients C",
        "latency (s)",
    );
    let mut t = Table::new("fig9").header(
        &std::iter::once("C".to_string())
            .chain(rounds_to.iter().map(|(n, _, _)| n.clone()))
            .collect::<Vec<_>>(),
    );
    let mut series: Vec<(String, Vec<(f64, f64)>)> = rounds_to
        .iter()
        .map(|(n, _, _)| (n.clone(), Vec::new()))
        .collect();
    // Consume in the exact construction order: C-major, then framework,
    // with one `seeds_per`-sized chunk per (C, framework) pair.
    let mut chunks = outs.chunks(seeds_per as usize);
    for &c in &sweep_c {
        let mut row = vec![c.to_string()];
        for (i, (_, _fw, r2t)) in rounds_to.iter().enumerate() {
            let chunk = chunks
                // audit:allow(R1, "the solve fan-out produced exactly one chunk per (C, framework) cell, in this same order")
                .next().expect("fig9 cell grid shape mismatch");
            let vals: Vec<f64> = chunk.iter().flatten().copied().collect();
            let per_round = mean(&vals);
            // Per-client data shrinks with C (D fixed): rounds per epoch
            // scale with D/(C·b); epochs-to-target held at the anchor.
            let scale = 5.0 / c as f64;
            let total = r2t * scale.max(0.05) * per_round;
            series[i].1.push((c as f64, total));
            row.push(format!("{total:.1}"));
        }
        t.row(&row);
    }
    for (name, pts) in &series {
        plot.series(name, pts);
    }
    println!("{}", plot.render());
    println!("{}", t.render());
    ctx.save("fig9.csv", &t.to_csv())?;
    ctx.save("fig9.txt", &plot.render())
}

/// Fig. 10 — total training latency vs dataset size D (C = 5).
pub fn fig10(ctx: &mut Ctx) -> Result<()> {
    let rounds = if ctx.quick { 250 } else { 400 };
    let dataset_anchor = if ctx.quick { 1500 } else { 8000 };
    let target = 0.75;
    let sweep_d: Vec<usize> = if ctx.quick {
        vec![2000, 4000, 8000]
    } else {
        vec![2000, 4000, 6000, 8000, 10000]
    };
    let frameworks = super::accuracy::curve_frameworks();
    let mut anchors: Vec<(String, Framework, f64)> = Vec::new();
    for (name, fw) in &frameworks {
        if matches!(fw, Framework::Epsl { phi } if *phi == 1.0) {
            continue;
        }
        let run =
            curve_run(ctx, "ham", true, name, *fw, 5, rounds, dataset_anchor)?;
        let r2t =
            run.rounds_to_accuracy(target).unwrap_or(rounds).max(1) as f64;
        anchors.push((name.clone(), *fw, r2t));
    }

    // Per-round latency is independent of D: evaluate each framework's
    // (C = 5) cell batch once, in parallel, and reuse across the D sweep.
    let seeds_per = 3u64;
    let fws: Vec<Framework> = anchors.iter().map(|(_, fw, _)| *fw).collect();
    let mut cells = Vec::new();
    framework_cells(ctx, &fws, 5, seeds_per, &mut cells);
    let outs = sweep::run_framework_cells(
        resnet18::profile_static(),
        &cells,
        par::max_threads(),
    );
    let per_round_by_fw: Vec<f64> = outs
        .chunks(seeds_per as usize)
        .map(|chunk| {
            let vals: Vec<f64> = chunk.iter().flatten().copied().collect();
            mean(&vals)
        })
        .collect();
    assert_eq!(per_round_by_fw.len(), anchors.len(), "fig10 cell grid");

    let mut plot = LinePlot::new(
        "Fig 10: total latency to target accuracy vs dataset size",
        "dataset size D",
        "latency (s)",
    );
    let mut t = Table::new("fig10").header(
        &std::iter::once("D".to_string())
            .chain(anchors.iter().map(|(n, _, _)| n.clone()))
            .collect::<Vec<_>>(),
    );
    let mut series: Vec<(String, Vec<(f64, f64)>)> =
        anchors.iter().map(|(n, _, _)| (n.clone(), Vec::new())).collect();
    for &d in &sweep_d {
        let mut row = vec![d.to_string()];
        for (i, (_, _fw, r2t)) in anchors.iter().enumerate() {
            let per_round = per_round_by_fw[i];
            // rounds-to-target scales with D (rounds/epoch ∝ D at fixed
            // C·b; epochs-to-target anchored).
            let total =
                r2t * (d as f64 / dataset_anchor as f64) * per_round;
            series[i].1.push((d as f64, total));
            row.push(format!("{total:.1}"));
        }
        t.row(&row);
    }
    for (name, pts) in &series {
        plot.series(name, pts);
    }
    println!("{}", plot.render());
    println!("{}", t.render());
    ctx.save("fig10.csv", &t.to_csv())?;
    ctx.save("fig10.txt", &plot.render())
}

/// Shared sweep driver for Figs. 11–12: builds the full
/// (x × scheme × seed) cell grid, fans it across cores, aggregates in
/// deterministic order.
fn scheme_sweep(ctx: &Ctx, xlabel: &str,
                xs: &[f64],
                mut make_net: impl FnMut(f64) -> crate::config::NetworkConfig,
                id: &str, title: &str) -> Result<()> {
    let profile = resnet18::profile_static();
    let seeds: u64 = if ctx.quick { 3 } else { 10 };
    let mut cells = Vec::new();
    for &x in xs {
        let net = make_net(x);
        for scheme in Scheme::all() {
            for s in 0..seeds {
                cells.push(SchemeCell {
                    net: net.clone(),
                    scheme,
                    dep_seed: 0xBA5E + s,
                    scheme_seed: 0xC0DE + s,
                    batch: ctx.cfg.train.batch,
                    phi: ctx.cfg.train.phi,
                });
            }
        }
    }
    let outs = sweep::run_scheme_cells(profile, &cells, par::max_threads());

    let mut t = Table::new(id).header(
        &std::iter::once(xlabel.to_string())
            .chain(Scheme::all().iter().map(|s| s.name().to_string()))
            .collect::<Vec<_>>(),
    );
    let mut plot = LinePlot::new(title, xlabel, "per-round latency (s)");
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Scheme::all()
        .iter()
        .map(|s| (s.name().to_string(), Vec::new()))
        .collect();
    // Consume in the exact construction order: x-major, then scheme, with
    // one `seeds`-sized chunk per (x, scheme) pair.
    let mut chunks = outs.chunks(seeds as usize);
    for &x in xs {
        let mut row = vec![format!("{x}")];
        for (si, _) in Scheme::all().iter().enumerate() {
            let chunk = chunks
                // audit:allow(R1, "the solve fan-out produced exactly one chunk per (x, scheme) cell, in this same order")
                .next().expect("scheme sweep cell grid shape mismatch");
            let vals: Vec<f64> = chunk.iter().flatten().copied().collect();
            let v = mean(&vals);
            series[si].1.push((x, v));
            row.push(format!("{v:.3}"));
        }
        t.row(&row);
    }
    for (name, pts) in &series {
        plot.series(name, pts);
    }
    println!("{}", plot.render());
    println!("{}", t.render());
    ctx.save(&format!("{id}.csv"), &t.to_csv())?;
    ctx.save(&format!("{id}.txt"), &plot.render())
}

/// Fig. 11 — per-round latency vs total bandwidth (5 schemes).
pub fn fig11(ctx: &mut Ctx) -> Result<()> {
    let xs: Vec<f64> = if ctx.quick {
        vec![100.0, 200.0, 300.0]
    } else {
        vec![100.0, 150.0, 200.0, 250.0, 300.0]
    };
    let base = ctx.cfg.net.clone();
    scheme_sweep(
        ctx,
        "total bandwidth (MHz)",
        &xs,
        move |mhz| base.clone().with_total_bandwidth(mhz * 1e6),
        "fig11",
        "Fig 11: per-round latency vs total bandwidth",
    )
}

/// Fig. 12 — per-round latency vs server computing capability.
pub fn fig12(ctx: &mut Ctx) -> Result<()> {
    let xs: Vec<f64> = if ctx.quick {
        vec![1.0, 5.0, 9.0]
    } else {
        vec![1.0, 3.0, 5.0, 7.0, 9.0]
    };
    let base = ctx.cfg.net.clone();
    scheme_sweep(
        ctx,
        "server compute (GHz eq.)",
        &xs,
        move |ghz| {
            let mut n = base.clone();
            n.f_server = ghz * 1e9;
            n
        },
        "fig12",
        "Fig 12: per-round latency vs server computing capability",
    )
}

/// One bandwidth point of Fig. 13 through the scenario engine.
///
/// Returns `(static ideal latency, fixed per-round latencies, oracle
/// per-round latencies)` over a **shared** realization sequence:
/// - the deployment + fading draws replay the pre-scenario RNG discipline
///   exactly (seed `0x13`, then per-round redraws), so the numbers are
///   bit-identical to the pre-refactor inline loop;
/// - "fixed" is [`ReoptPolicy::Never`] on a [`ScenarioSpec::fading`]
///   scenario (one BCD solve on average gains, held fixed);
/// - "oracle" is [`ReoptPolicy::EveryK`]`(1)` with the shortened BCD
///   budget (re-solve on every realization).
pub fn fig13_point(net: &NetworkConfig, batch: usize, phi: f64,
                   n_rounds: usize, threads: usize)
    -> Result<(f64, Vec<Option<f64>>, Vec<Option<f64>>)> {
    let profile = resnet18::profile_static();
    let mut rng = Rng::new(0x13);
    let dep = Deployment::generate(net, &mut rng);
    // The fading expansion continues `rng` exactly like the legacy
    // per-round `ChannelRealization::sample` loop.
    let fading = Scenario::from_deployment(
        net.clone(),
        dep.clone(),
        ScenarioSpec::fading(n_rounds),
        &mut rng,
    )?;
    // The static benchmark draws nothing further from the stream.
    let ideal = Scenario::from_deployment(
        net.clone(),
        dep,
        ScenarioSpec::static_channel(1),
        &mut rng,
    )?;
    let fixed = scenario::run_policy(
        &fading,
        profile,
        &RunOptions {
            policy: ReoptPolicy::Never,
            bcd: bcd::BcdOptions::default(),
            batch,
            phi,
            threads,
            timeline_mode: Mode::Barrier,
        },
    );
    let oracle = scenario::run_policy(
        &fading,
        profile,
        &RunOptions {
            policy: ReoptPolicy::EveryK(1),
            bcd: bcd::BcdOptions { max_iters: 6, tol: 1e-4 },
            batch,
            phi,
            threads,
            timeline_mode: Mode::Barrier,
        },
    );
    // This repeats the fixed run's average-gains solve (bit-identical
    // inputs → bit-identical decision): one redundant default-budget BCD
    // per bandwidth point, ~2% of the oracle cost, accepted to keep the
    // figure a pure composition of policy runs.
    let stat = scenario::run_policy(
        &ideal,
        profile,
        &RunOptions {
            policy: ReoptPolicy::Never,
            bcd: bcd::BcdOptions::default(),
            batch,
            phi,
            threads,
            timeline_mode: Mode::Barrier,
        },
    );
    let t_static =
        stat.rounds.first().and_then(|r| r.latency).unwrap_or(f64::NAN);
    Ok((t_static, fixed.latencies(), oracle.latencies()))
}

/// Fig. 13 — robustness of the layer-split decision to channel variation.
///
/// The decision (subchannels, powers, cut) is optimized ONCE on average
/// gains and held fixed, as in the paper ("the cut layer decision, once
/// determined, could last for a long period"). Three series:
/// - static ideal: fixed decision on the unrealistically static channel;
/// - fixed decision under per-round shadow-fading redraws;
/// - oracle: re-optimized per realization (upper bound on what adapting
///   every round could buy).
/// Robustness = the fixed decision tracks the oracle closely.
///
/// Since the scenario refactor this is a thin special case of the
/// `scenario` engine (see [`fig13_point`]); the oracle's per-realization
/// solve blocks fan across cores. Fixed and oracle means are **paired**
/// per realization: if either side's solve fails, the realization is
/// dropped from both means and reported (the pre-fix code `.flatten()`-ed
/// oracle failures away, silently averaging different realization sets).
pub fn fig13(ctx: &mut Ctx) -> Result<()> {
    let xs: Vec<f64> = if ctx.quick {
        vec![100.0, 200.0, 300.0]
    } else {
        vec![100.0, 150.0, 200.0, 250.0, 300.0]
    };
    let n_rounds = if ctx.quick { 15 } else { 60 };
    let mut t = Table::new("fig13").header(&[
        "total bandwidth (MHz)",
        "static channel (ideal)",
        "fixed decision, varying channel",
        "re-optimized each round (oracle)",
        "fixed/oracle",
    ]);
    let mut plot = LinePlot::new(
        "Fig 13: channel variation robustness",
        "total bandwidth (MHz)",
        "per-round latency (s)",
    );
    let mut s_static = Vec::new();
    let mut s_fixed = Vec::new();
    let mut s_oracle = Vec::new();
    for &mhz in &xs {
        let net = ctx.cfg.net.clone().with_total_bandwidth(mhz * 1e6);
        let (t_static, fixed, oracle) = fig13_point(
            &net,
            ctx.cfg.train.batch,
            ctx.cfg.train.phi,
            n_rounds,
            par::max_threads(),
        )?;
        let p = scenario::pair_latencies(&fixed, &oracle);
        if p.n_dropped > 0 {
            println!(
                "  fig13 @ {mhz} MHz: dropped {}/{n_rounds} realizations \
                 (solve failures) from both the fixed and oracle means",
                p.n_dropped
            );
        }
        s_static.push((mhz, t_static));
        s_fixed.push((mhz, p.fixed_mean));
        s_oracle.push((mhz, p.oracle_mean));
        t.row(&[
            format!("{mhz}"),
            format!("{t_static:.3}"),
            format!("{:.3}", p.fixed_mean),
            format!("{:.3}", p.oracle_mean),
            format!("{:.3}", p.ratio()),
        ]);
    }
    plot.series("static (ideal)", &s_static);
    plot.series("fixed decision", &s_fixed);
    plot.series("oracle (re-opt)", &s_oracle);
    println!("{}", plot.render());
    println!("{}", t.render());
    ctx.save("fig13.csv", &t.to_csv())?;
    ctx.save("fig13.txt", &plot.render())
}

/// Fig. 13b — when does "optimize once" stop being good enough?
///
/// Sweeps the block-fading redraw period (channel coherence, in rounds)
/// against the re-optimization policy at the default bandwidth. Each cell
/// is a full scenario run: expand the dynamics from the cell's seed, run
/// the policy, average the per-round eq. 23 latency. All four policies
/// see the *same* realization sequences (same seeds), so columns are
/// directly comparable; the grid fans across cores via
/// [`scenario::run_scenario_cells`] (bit-identical to serial).
pub fn fig13b(ctx: &mut Ctx) -> Result<()> {
    let profile = resnet18::profile_static();
    let n_rounds = if ctx.quick { 16 } else { 64 };
    let seeds: u64 = if ctx.quick { 2 } else { 5 };
    let periods: Vec<usize> = if ctx.quick {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    };
    let policies = [
        ReoptPolicy::Never,
        ReoptPolicy::EveryK(8),
        ReoptPolicy::OnRegression(1.2),
        ReoptPolicy::EveryK(1), // oracle — last so the ratio column reads off it
    ];
    let bcd_opts = bcd::BcdOptions { max_iters: 6, tol: 1e-4 };
    let mut cells = Vec::new();
    for &period in &periods {
        for &policy in &policies {
            for s in 0..seeds {
                cells.push(ScenarioCell {
                    net: ctx.cfg.net.clone(),
                    spec: ScenarioSpec::block_fading(n_rounds, period),
                    policy,
                    bcd: bcd_opts,
                    seed: 0x13B0 + s,
                    batch: ctx.cfg.train.batch,
                    phi: ctx.cfg.train.phi,
                    timeline_mode: Mode::Barrier,
                });
            }
        }
    }
    let outs =
        scenario::run_scenario_cells(profile, &cells, par::max_threads());

    let mut header = vec!["redraw period (rounds)".to_string()];
    header.extend(policies.iter().map(|p| p.name()));
    header.push("never/oracle".into());
    let mut t = Table::new("fig13b").header(&header);
    let mut solves_t = Table::new("fig13b optimizer invocations").header(
        &std::iter::once("redraw period (rounds)".to_string())
            .chain(policies.iter().map(|p| p.name()))
            .collect::<Vec<_>>(),
    );
    let mut plot = LinePlot::new(
        "Fig 13b: re-optimization policy vs channel coherence",
        "fading redraw period (rounds)",
        "mean per-round latency (s)",
    );
    let mut series: Vec<(String, Vec<(f64, f64)>)> =
        policies.iter().map(|p| (p.name(), Vec::new())).collect();
    // Consume in the exact construction order: period-major, then policy,
    // with one `seeds`-sized chunk per (period, policy) pair.
    let mut chunks = outs.chunks(seeds as usize);
    for &period in &periods {
        let mut row = vec![period.to_string()];
        let mut solves_row = vec![period.to_string()];
        let mut means = Vec::new();
        for (pi, policy) in policies.iter().enumerate() {
            let chunk = chunks
                // audit:allow(R1, "the solve fan-out produced exactly one chunk per (period, policy) cell, in this same order")
                .next().expect("fig13b cell grid shape mismatch");
            // A failed cell (invalid spec, or every solve failed) must
            // not silently enter the mean as 0.0 — drop and report it,
            // like fig13's paired statistics.
            let mut vals = Vec::new();
            let mut n_solves = 0usize;
            let mut dropped_cells = 0usize;
            let mut failed_rounds = 0usize;
            for s in chunk.iter() {
                match s {
                    Some(sum) if sum.n_rounds > 0 => {
                        vals.push(sum.mean_latency);
                        n_solves += sum.n_solves;
                        failed_rounds += sum.n_failed;
                    }
                    _ => dropped_cells += 1,
                }
            }
            if dropped_cells > 0 || failed_rounds > 0 {
                println!(
                    "  fig13b period {period} / {}: dropped \
                     {dropped_cells} cell(s), {failed_rounds} failed \
                     round(s) (solve failures)",
                    policy.name()
                );
            }
            let v = if vals.is_empty() { f64::NAN } else { mean(&vals) };
            means.push(v);
            series[pi].1.push((period as f64, v));
            row.push(format!("{v:.3}"));
            // Mean solves per *surviving* cell (the same cell set the
            // latency column averages).
            solves_row.push(if vals.is_empty() {
                "n/a".to_string()
            } else {
                format!("{:.1}", n_solves as f64 / vals.len() as f64)
            });
        }
        let oracle_mean = means[policies.len() - 1];
        let ratio = if oracle_mean.is_finite() {
            means[0] / oracle_mean.max(1e-12)
        } else {
            f64::NAN
        };
        row.push(format!("{ratio:.3}"));
        t.row(&row);
        solves_t.row(&solves_row);
    }
    for (name, pts) in &series {
        plot.series(name, pts);
    }
    println!("{}", plot.render());
    println!("{}", t.render());
    println!("{}", solves_t.render());
    ctx.save("fig13b.csv", &t.to_csv())?;
    ctx.save("fig13b.txt", &plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::ChannelRealization;
    use crate::optim::Problem;

    /// The acceptance test for the scenario refactor: `fig13_point` must
    /// reproduce the pre-refactor inline fig13 pipeline bit for bit —
    /// same RNG stream, same BCD solves, same fixed/oracle evaluations.
    #[test]
    fn fig13_point_matches_legacy_inline_pipeline() {
        let mut net = NetworkConfig::default();
        net.n_clients = 3;
        net.n_subchannels = 6;
        let profile = resnet18::profile_static();
        let n_rounds = 4;

        // --- the pre-refactor fig13 body, inlined verbatim ---
        let mut rng = Rng::new(0x13);
        let dep = Deployment::generate(&net, &mut rng);
        let avg = ChannelRealization::average(&dep);
        let prob = Problem {
            cfg: &net,
            profile,
            dep: &dep,
            ch: &avg,
            batch: 64,
            phi: 0.5,
        };
        let d = bcd::solve(&prob, bcd::BcdOptions::default())
            .unwrap()
            .decision;
        let t_static_legacy = prob.objective(&d);
        let chs: Vec<ChannelRealization> = (0..n_rounds)
            .map(|_| ChannelRealization::sample(&dep, &mut rng))
            .collect();
        let fixed_legacy: Vec<f64> = chs
            .iter()
            .map(|ch| Problem { ch, ..prob.clone() }.objective(&d))
            .collect();
        let oracle_legacy = sweep::run_oracle_cells(
            &prob,
            &chs,
            bcd::BcdOptions { max_iters: 6, tol: 1e-4 },
            2,
        );

        // --- the scenario-engine path ---
        let (t_static, fixed, oracle) =
            fig13_point(&net, 64, 0.5, n_rounds, 2).unwrap();

        assert_eq!(
            t_static.to_bits(),
            t_static_legacy.to_bits(),
            "static ideal diverged: {t_static} vs {t_static_legacy}"
        );
        assert_eq!(fixed.len(), n_rounds);
        for (i, (a, b)) in fixed.iter().zip(&fixed_legacy).enumerate() {
            assert_eq!(
                a.map(f64::to_bits),
                Some(b.to_bits()),
                "fixed series diverged at realization {i}"
            );
        }
        assert_eq!(oracle.len(), n_rounds);
        for (i, (a, b)) in oracle.iter().zip(&oracle_legacy).enumerate() {
            assert_eq!(
                a.map(f64::to_bits),
                b.map(f64::to_bits),
                "oracle series diverged at realization {i}"
            );
        }
    }

    /// The sweep path is bit-identical for any thread count.
    #[test]
    fn fig13_point_thread_invariant() {
        let mut net = NetworkConfig::default();
        net.n_clients = 3;
        net.n_subchannels = 6;
        let serial = fig13_point(&net, 64, 0.5, 4, 1).unwrap();
        let par8 = fig13_point(&net, 64, 0.5, 4, 8).unwrap();
        assert_eq!(serial.0.to_bits(), par8.0.to_bits());
        for (a, b) in serial.1.iter().zip(&par8.1) {
            assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits));
        }
        for (a, b) in serial.2.iter().zip(&par8.2) {
            assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits));
        }
    }
}
