//! Parallel sweep engine for the §V-model experiment grids.
//!
//! Figs. 9–13 evaluate hundreds of independent cells — each one a full
//! deployment draw plus a resource-management solve over the *analytical*
//! latency model (no PJRT runtime is involved, so the work is `Send`). The
//! engine fans those cells across cores via [`par::parallel_map`] with
//! deterministic per-cell seeding: every cell derives its RNGs from seeds
//! stored in the cell itself, so the result vector is **bit-identical** to
//! the serial loop for any thread count (set `EPSL_THREADS=1` to force
//! serial execution).

use crate::channel::{ChannelRealization, Deployment};
use crate::config::NetworkConfig;
use crate::latency::frameworks::{round_latency, Framework};
use crate::latency::LatencyInputs;
use crate::optim::baselines::{self, Scheme};
use crate::optim::{bcd, Problem};
use crate::profile::NetworkProfile;
use crate::util::par;
use crate::util::rng::Rng;

/// One (deployment seed × scheme) cell of a Figs. 11–12-style sweep.
#[derive(Debug, Clone)]
pub struct SchemeCell {
    pub net: NetworkConfig,
    pub scheme: Scheme,
    /// Seed for the deployment draw.
    pub dep_seed: u64,
    /// Seed for the scheme's own randomness (random-cut baselines).
    pub scheme_seed: u64,
    pub batch: usize,
    pub phi: f64,
}

/// Evaluate one scheme cell: draw the deployment, solve the scheme, return
/// the reference eq. 23 objective (`None` if the scheme solve fails).
pub fn eval_scheme_cell(profile: &NetworkProfile, cell: &SchemeCell)
    -> Option<f64> {
    let mut rng = Rng::new(cell.dep_seed);
    let dep = Deployment::generate(&cell.net, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cell.net,
        profile,
        dep: &dep,
        ch: &ch,
        batch: cell.batch,
        phi: cell.phi,
    };
    let mut srng = Rng::new(cell.scheme_seed);
    baselines::solve(&prob, cell.scheme, &mut srng)
        .ok()
        .map(|d| prob.objective(&d))
}

/// Fan a batch of scheme cells across `threads` workers; results come back
/// in input order.
pub fn run_scheme_cells(profile: &NetworkProfile, cells: &[SchemeCell],
                        threads: usize) -> Vec<Option<f64>> {
    par::parallel_map(cells, threads, |_, cell| {
        eval_scheme_cell(profile, cell)
    })
}

/// One (deployment seed × framework) cell of the Figs. 9–10 per-round
/// latency sweeps: BCD-optimized resources, framework-specific round
/// latency.
#[derive(Debug, Clone)]
pub struct FrameworkCell {
    pub net: NetworkConfig,
    pub fw: Framework,
    pub dep_seed: u64,
    pub batch: usize,
}

/// Evaluate one framework cell (`None` if the BCD solve fails).
pub fn eval_framework_cell(profile: &NetworkProfile, cell: &FrameworkCell)
    -> Option<f64> {
    let mut rng = Rng::new(cell.dep_seed);
    let dep = Deployment::generate(&cell.net, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cell.net,
        profile,
        dep: &dep,
        ch: &ch,
        batch: cell.batch,
        phi: cell.fw.phi(),
    };
    let d = bcd::solve(&prob, bcd::BcdOptions::default()).ok()?.decision;
    let (up, dn, bc) = prob.rates(&d);
    let inp = LatencyInputs {
        profile,
        cut: d.cut.as_uniform()?,
        batch: cell.batch,
        phi: cell.fw.phi(),
        f_server: cell.net.f_server,
        kappa_server: cell.net.kappa_server,
        kappa_client: cell.net.kappa_client,
        f_clients: dep.f_clients(),
        uplink: &up,
        downlink: &dn,
        broadcast: bc,
        uplink_comp: cell.net.uplink_compression,
    };
    Some(round_latency(cell.fw, &inp).round_total())
}

/// Fan a batch of framework cells across `threads` workers (input order
/// preserved).
pub fn run_framework_cells(profile: &NetworkProfile, cells: &[FrameworkCell],
                           threads: usize) -> Vec<Option<f64>> {
    par::parallel_map(cells, threads, |_, cell| {
        eval_framework_cell(profile, cell)
    })
}

/// Oracle re-optimization for Fig. 13: solve BCD per channel realization
/// in parallel, each cell a copy of `base` with its own channel
/// (realizations are pre-sampled serially to preserve the RNG stream).
pub fn run_oracle_cells(base: &Problem, chs: &[ChannelRealization],
                        opts: bcd::BcdOptions, threads: usize)
    -> Vec<Option<f64>> {
    par::parallel_map(chs, threads, |_, ch| {
        let prob = Problem { ch, ..base.clone() };
        bcd::solve(&prob, opts).ok().map(|r| r.objective)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::resnet18;
    use crate::util::table::Table;

    fn small_cells() -> (NetworkProfile, Vec<SchemeCell>) {
        let mut net = NetworkConfig::default();
        net.n_clients = 3;
        net.n_subchannels = 6;
        let profile = resnet18::profile();
        let mut cells = Vec::new();
        for scheme in [Scheme::BaselineA, Scheme::BaselineB, Scheme::BaselineD]
        {
            for s in 0..3u64 {
                cells.push(SchemeCell {
                    net: net.clone(),
                    scheme,
                    dep_seed: 0xBA5E + s,
                    scheme_seed: 0xC0DE + s,
                    batch: 64,
                    phi: 0.5,
                });
            }
        }
        (profile, cells)
    }

    #[test]
    fn parallel_sweep_bit_identical_to_serial() {
        let (profile, cells) = small_cells();
        let serial = run_scheme_cells(&profile, &cells, 1);
        let par4 = run_scheme_cells(&profile, &cells, 4);
        assert_eq!(serial.len(), par4.len());
        for (i, (a, b)) in serial.iter().zip(&par4).enumerate() {
            match (a, b) {
                (Some(x), Some(y)) => assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "cell {i}: serial {x} vs parallel {y}"
                ),
                (None, None) => {}
                _ => panic!("cell {i}: success/failure diverged"),
            }
        }
        // All these schemes solve on the default-config fixture.
        assert!(serial.iter().all(|v| v.is_some()));
    }

    #[test]
    fn parallel_sweep_renders_byte_identical_tables() {
        // The figure pipeline formats cell means into ASCII tables; the
        // rendered artifact must not depend on the thread count.
        let (profile, cells) = small_cells();
        let render = |objs: &[Option<f64>]| {
            let mut t = Table::new("sweep determinism")
                .header(&["scheme", "mean latency (s)"]);
            let per_scheme = 3;
            for (si, chunk) in objs.chunks(per_scheme).enumerate() {
                let vals: Vec<f64> = chunk.iter().flatten().copied().collect();
                t.row(&[
                    format!("scheme {si}"),
                    format!("{:.6}", crate::util::stats::mean(&vals)),
                ]);
            }
            t.render()
        };
        let serial = render(&run_scheme_cells(&profile, &cells, 1));
        let par3 = render(&run_scheme_cells(&profile, &cells, 3));
        let par8 = render(&run_scheme_cells(&profile, &cells, 8));
        assert_eq!(serial, par3);
        assert_eq!(serial, par8);
    }

    #[test]
    fn oracle_matches_scenario_every_round() {
        // EveryK(1) through the scenario runner must reproduce the
        // pre-scenario fig13 oracle path (run_oracle_cells) bit-for-bit
        // on the same realizations. (Moved here from scenario::run's
        // tests: scenario sits below experiments in the layering DAG.)
        use crate::scenario::{
            run_policy, ReoptPolicy, RunOptions, Scenario, ScenarioSpec,
        };
        use crate::timeline::Mode;

        let net = NetworkConfig::default().with_clients(3);
        let n_rounds = 5;
        let mut rng = Rng::new(0x13);
        let dep = Deployment::generate(&net, &mut rng);
        let sc = Scenario::from_deployment(
            net.clone(),
            dep,
            ScenarioSpec::fading(n_rounds),
            &mut rng,
        )
        .unwrap();
        let profile = resnet18::profile();
        let bcd_opts = bcd::BcdOptions { max_iters: 6, tol: 1e-4 };
        let avg = ChannelRealization::average(&sc.roster);
        let base = Problem {
            cfg: &net,
            profile: &profile,
            dep: &sc.roster,
            ch: &avg,
            batch: 64,
            phi: 0.5,
        };
        let chs: Vec<ChannelRealization> =
            sc.rounds.iter().map(|r| r.ch.clone()).collect();
        let legacy = run_oracle_cells(&base, &chs, bcd_opts, 2);
        let out = run_policy(
            &sc,
            &profile,
            &RunOptions {
                policy: ReoptPolicy::EveryK(1),
                bcd: bcd_opts,
                batch: 64,
                phi: 0.5,
                threads: 2,
                timeline_mode: Mode::Barrier,
            },
        );
        assert_eq!(out.rounds.len(), legacy.len());
        for (r, l) in out.rounds.iter().zip(&legacy) {
            assert_eq!(
                r.latency.map(f64::to_bits),
                l.map(f64::to_bits),
                "oracle diverged at round {}",
                r.round
            );
        }
    }

    #[test]
    fn framework_cells_deterministic_across_threads() {
        let mut net = NetworkConfig::default();
        net.n_clients = 3;
        net.n_subchannels = 6;
        let profile = resnet18::profile();
        let mut cells = Vec::new();
        for fw in [Framework::Psl, Framework::Epsl { phi: 0.5 }] {
            for s in 0..2u64 {
                cells.push(FrameworkCell {
                    net: net.clone(),
                    fw,
                    dep_seed: 0xF16_0000 + s,
                    batch: 64,
                });
            }
        }
        let serial = run_framework_cells(&profile, &cells, 1);
        let par4 = run_framework_cells(&profile, &cells, 4);
        for (a, b) in serial.iter().zip(&par4) {
            assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits));
        }
        assert!(serial.iter().all(|v| v.is_some()));
    }
}
