//! Experiment registry: one generator per table/figure in the paper's
//! evaluation (§VII). `repro figures --id <ID>` regenerates a single
//! artifact; `--all` regenerates everything into `results/`.
//!
//! | id     | paper artifact |
//! |--------|----------------|
//! | table1 | framework capability matrix |
//! | table4 | ResNet-18 layer profile |
//! | table5 | converged accuracy vs C (HAM-like, IID) |
//! | fig4   | accuracy vs round + per-round latency bars (C=5) |
//! | fig7   | accuracy curves, MNIST-like, IID + non-IID |
//! | fig8   | accuracy curves, HAM-like, IID + non-IID |
//! | fig9   | total latency to target accuracy vs C |
//! | fig10  | total latency vs dataset size |
//! | fig11  | per-round latency vs total bandwidth (5 schemes) |
//! | fig12  | per-round latency vs server compute (5 schemes) |
//! | fig13  | robustness to channel variation |
//! | fig13b | re-optimization policy vs channel coherence (scenario sweep; repo extension) |
//! | fig_pipeline | barrier vs pipelined timeline latency across cuts and C (repo extension) |
//! | fig_hetero_cut | per-client cut refinement vs uniform optimum under compute heterogeneity (repo extension) |
//!
//! Training-backed experiments (table5, fig4, fig7–10) run the real
//! coordinator over the selected backend — PJRT when artifacts exist,
//! the pure-Rust native backend otherwise, so they run offline and in
//! CI; `quick` mode shrinks rounds/sweeps so the full set completes in
//! minutes (the full-fidelity settings are the documented defaults in
//! EXPERIMENTS.md). The extra `accuracy-smoke` id is the CI guard that
//! keeps the training path executable.

pub mod accuracy;
pub mod hetero_cut;
pub mod latency_figs;
pub mod pipeline;
pub mod sweep;
pub mod tables;

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::Config;
use crate::error::{Error, Result};
use crate::metrics::RunMetrics;
use crate::runtime::artifact::Manifest;
use crate::runtime::Backend;

/// Shared context handed to every experiment.
pub struct Ctx<'a> {
    pub cfg: Config,
    /// Training backend (PJRT or native). `None` only in latency-only
    /// contexts (e.g. unit tests) — `repro figures` always selects one.
    pub rt: Option<&'a dyn Backend>,
    pub manifest: Option<&'a Manifest>,
    pub out_dir: String,
    /// Reduced-budget mode (fewer rounds / sweep points).
    pub quick: bool,
    /// Cache of training runs shared across experiments in one invocation,
    /// keyed by a descriptive string.
    pub run_cache: BTreeMap<String, RunMetrics>,
}

impl<'a> Ctx<'a> {
    pub fn new(cfg: Config, rt: Option<&'a dyn Backend>,
               manifest: Option<&'a Manifest>, out_dir: &str, quick: bool)
        -> Self {
        Ctx {
            cfg,
            rt,
            manifest,
            out_dir: out_dir.to_string(),
            quick,
            run_cache: BTreeMap::new(),
        }
    }

    pub fn runtime(&self) -> Result<&'a dyn Backend> {
        self.rt.ok_or_else(|| {
            Error::Artifact(
                "this experiment trains models but no backend was \
                 selected (pass --backend native, or build artifacts for \
                 PJRT)"
                    .into(),
            )
        })
    }

    pub fn manifest(&self) -> Result<&'a Manifest> {
        self.manifest.ok_or_else(|| {
            Error::Artifact("manifest unavailable — run `make artifacts`".into())
        })
    }

    /// Write a result file under `out_dir`.
    pub fn save(&self, name: &str, contents: &str) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = Path::new(&self.out_dir).join(name);
        std::fs::write(&path, contents)?;
        println!("wrote {}", path.display());
        Ok(())
    }
}

/// All experiment ids in regeneration order.
pub const ALL_IDS: &[&str] = &[
    "table1", "table4", "fig11", "fig12", "fig13", "fig13b",
    "fig_pipeline", "fig_hetero_cut", "table5", "fig4", "fig7", "fig8",
    "fig9", "fig10",
];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &mut Ctx) -> Result<()> {
    println!("\n=== experiment {id} ({}) ===",
             if ctx.quick { "quick" } else { "full" });
    match id {
        // CI guard, not a paper figure (hence not in ALL_IDS): a short
        // fig4-style run that fails loudly if the training path cannot
        // execute — so it can never silently regress to all-skip.
        "accuracy-smoke" => accuracy::accuracy_smoke(ctx),
        "table1" => tables::table1(ctx),
        "table4" => tables::table4(ctx),
        "table5" => tables::table5(ctx),
        "fig4" => accuracy::fig4(ctx),
        "fig7" => accuracy::fig7(ctx),
        "fig8" => accuracy::fig8(ctx),
        "fig9" => latency_figs::fig9(ctx),
        "fig10" => latency_figs::fig10(ctx),
        "fig11" => latency_figs::fig11(ctx),
        "fig12" => latency_figs::fig12(ctx),
        "fig13" => latency_figs::fig13(ctx),
        "fig13b" => latency_figs::fig13b(ctx),
        "fig_pipeline" => pipeline::fig_pipeline(ctx),
        "fig_hetero_cut" => hetero_cut::fig_hetero_cut(ctx),
        other => Err(Error::Config(format!(
            "unknown experiment '{other}' (known: {ALL_IDS:?})"
        ))),
    }
}

/// Run every registered experiment, collecting per-figure failures
/// instead of aborting the sweep on the first one. Failures are reported
/// together at the end and propagate as one error (→ non-zero exit), so
/// a single broken figure can no longer take down the regeneration of
/// everything after it.
pub fn run_all(ctx: &mut Ctx) -> Result<()> {
    run_ids(ALL_IDS, ctx)
}

/// [`run_all`] over an explicit id list (exposed for tests).
pub fn run_ids(ids: &[&str], ctx: &mut Ctx) -> Result<()> {
    let mut failed: Vec<String> = Vec::new();
    for id in ids {
        if let Err(e) = run(id, ctx) {
            eprintln!("experiment {id} FAILED: {e}");
            failed.push(format!("{id}: {e}"));
        }
    }
    if failed.is_empty() {
        Ok(())
    } else {
        Err(Error::Runtime(format!(
            "{}/{} experiments failed:\n  {}",
            failed.len(),
            ids.len(),
            failed.join("\n  ")
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_rejected() {
        let mut ctx = Ctx::new(Config::new(), None, None, "/tmp/epsl_res", true);
        assert!(run("nope", &mut ctx).is_err());
    }

    #[test]
    fn all_ids_dispatch() {
        // Profile/capability experiments must run without artifacts.
        for id in ["table1", "table4"] {
            let mut ctx =
                Ctx::new(Config::new(), None, None, "/tmp/epsl_res", true);
            run(id, &mut ctx).unwrap();
        }
    }

    #[test]
    fn training_experiments_require_runtime() {
        let mut ctx = Ctx::new(Config::new(), None, None, "/tmp/epsl_res", true);
        assert!(run("table5", &mut ctx).is_err());
    }

    #[test]
    fn run_ids_collects_failures_and_keeps_going() {
        // A failing id in the middle must not stop the sweep: the ids
        // after it still run, and the aggregate error names the failure.
        let dir = "/tmp/epsl_res_run_ids";
        let _ = std::fs::remove_dir_all(dir);
        let mut ctx = Ctx::new(Config::new(), None, None, dir, true);
        let e = run_ids(&["table1", "nope", "table4"], &mut ctx)
            .unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("1/3"), "{msg}");
        assert!(msg.contains("nope"), "{msg}");
        // table4 (after the failure) still produced its artifact.
        assert!(
            std::path::Path::new(dir).join("table4.csv").exists()
                || std::path::Path::new(dir).join("table4.txt").exists(),
            "table4 did not run after the failed id"
        );
        // An all-good list is Ok.
        assert!(run_ids(&["table1", "table4"], &mut ctx).is_ok());
    }
}
