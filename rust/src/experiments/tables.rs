//! Tables I, IV and V.

use crate::error::Result;
use crate::latency::frameworks::Framework;
use crate::profile::resnet18;
use crate::util::table::Table;

use super::Ctx;

/// Table I — qualitative framework comparison.
pub fn table1(ctx: &mut Ctx) -> Result<()> {
    let mut t = Table::new("Table I: FL / vanilla SL / SFL / PSL / EPSL")
        .header(&["property", "FL", "vanilla SL", "SFL", "PSL", "EPSL"]);
    let frameworks = [
        Framework::VanillaSl,
        Framework::Sfl,
        Framework::Psl,
        Framework::Epsl { phi: 0.5 },
    ];
    let yn = |b: bool| if b { "Yes" } else { "No" };
    let caps: Vec<(bool, bool, bool, bool, bool)> =
        frameworks.iter().map(|f| f.capabilities()).collect();
    // FL column is fixed by the paper: no offload, parallel, model
    // exchange, no dim reduction, no raw-data access.
    t.row(&[
        "partial computation offloading",
        "No",
        yn(caps[0].0),
        yn(caps[1].0),
        yn(caps[2].0),
        yn(caps[3].0),
    ]);
    t.row(&[
        "parallel computing",
        "Yes",
        yn(caps[0].1),
        yn(caps[1].1),
        yn(caps[2].1),
        yn(caps[3].1),
    ]);
    t.row(&[
        "model exchange",
        "Yes",
        yn(caps[0].2),
        yn(caps[1].2),
        yn(caps[2].2),
        yn(caps[3].2),
    ]);
    t.row(&[
        "activations' gradients' dimension reduction",
        "No",
        yn(caps[0].3),
        yn(caps[1].3),
        yn(caps[2].3),
        yn(caps[3].3),
    ]);
    t.row(&[
        "access to raw data",
        "No",
        yn(caps[0].4),
        yn(caps[1].4),
        yn(caps[2].4),
        yn(caps[3].4),
    ]);
    println!("{}", t.render());
    ctx.save("table1.csv", &t.to_csv())?;
    ctx.save("table1.txt", &t.render())
}

/// Table IV — the ResNet-18 profile with derived ρ/ϖ/ψ columns.
pub fn table4(ctx: &mut Ctx) -> Result<()> {
    let p = resnet18::profile_static();
    let mut t = Table::new("Table IV: ResNet-18 network parameters").header(&[
        "layer", "size (MiB)", "FP (MFLOP)", "smashed (MiB)", "rho_j (MFLOP)",
        "varpi_j (MFLOP)", "psi_j (Mbit)",
    ]);
    for (j, l) in p.layers.iter().enumerate() {
        let cut = j + 1;
        let psi = if cut < p.n_layers() {
            format!("{:.4}", p.psi_bits(cut) / 1e6)
        } else {
            "-".into()
        };
        t.row(&[
            l.name.to_string(),
            format!("{:.4}", l.params_mib),
            format!("{:.4}", l.fp_mflops),
            format!("{:.4}", l.smashed_mib),
            format!("{:.3}", p.rho(cut) / 1e6),
            format!("{:.3}", p.varpi(cut) / 1e6),
            psi,
        ]);
    }
    println!("{}", t.render());
    ctx.save("table4.csv", &t.to_csv())?;
    ctx.save("table4.txt", &t.render())
}

/// Table V — converged test accuracy (HAM-like, IID) vs client count.
pub fn table5(ctx: &mut Ctx) -> Result<()> {
    // Fail fast if artifacts are missing (before any table output).
    let _ = ctx.runtime()?;
    let _ = ctx.manifest()?;
    let (client_counts, rounds, dataset): (Vec<usize>, usize, usize) =
        if ctx.quick {
            (vec![5, 10], 250, 1500)
        } else {
            (vec![5, 10, 15], 400, 8000)
        };
    let frameworks: Vec<(String, Framework)> = vec![
        ("vanilla SL".into(), Framework::VanillaSl),
        ("SFL".into(), Framework::Sfl),
        ("PSL".into(), Framework::Psl),
        ("EPSL(0.5)".into(), Framework::Epsl { phi: 0.5 }),
        ("EPSL(1.0)".into(), Framework::Epsl { phi: 1.0 }),
    ];
    let mut t = Table::new("Table V: converged test accuracy, HAM-like IID")
        .header(
            &std::iter::once("framework".to_string())
                .chain(client_counts.iter().map(|c| format!("C={c}")))
                .collect::<Vec<_>>(),
        );
    for (name, fw) in &frameworks {
        let mut row = vec![name.clone()];
        for &c in &client_counts {
            let run = super::accuracy::curve_run(
                ctx, "ham", true, name, *fw, c, rounds, dataset,
            )?;
            let acc = run.converged_accuracy(3);
            println!("  {name} C={c}: acc={acc:.3}");
            row.push(format!("{:.1}%", 100.0 * acc));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    ctx.save("table5.csv", &t.to_csv())?;
    ctx.save("table5.txt", &t.render())
}
