//! Accuracy-curve experiments: Fig. 4 (curves + latency bars) and
//! Figs. 7–8 (MNIST-like / HAM-like under IID and non-IID).

use crate::coordinator::{train, TrainerOptions};
use crate::error::Result;
use crate::latency::frameworks::Framework;
use crate::metrics::RunMetrics;
use crate::util::table::{bar_chart, LinePlot, Table};

use super::Ctx;

pub(crate) fn curve_frameworks() -> Vec<(String, Framework)> {
    vec![
        ("vanilla SL".into(), Framework::VanillaSl),
        ("SFL".into(), Framework::Sfl),
        ("PSL".into(), Framework::Psl),
        ("EPSL(0.5)".into(), Framework::Epsl { phi: 0.5 }),
        ("EPSL(1.0)".into(), Framework::Epsl { phi: 1.0 }),
    ]
}

/// Train (cached) one curve run.
pub(crate) fn curve_run(ctx: &mut Ctx, family: &str, iid: bool,
                        name: &str, fw: Framework, n_clients: usize,
                        rounds: usize, dataset: usize)
    -> Result<RunMetrics> {
    let key = format!(
        "{family}-{}-{name}-c{n_clients}-r{rounds}-d{dataset}",
        if iid { "iid" } else { "noniid" }
    );
    if let Some(r) = ctx.run_cache.get(&key) {
        return Ok(r.clone());
    }
    let rt = ctx.runtime()?;
    let manifest = ctx.manifest()?;
    let opts = TrainerOptions {
        family: family.into(),
        framework: fw,
        n_clients,
        iid,
        rounds,
        eval_every: 10,
        dataset_size: dataset,
        test_size: 512,
        eta_c: 0.1,
        eta_s: 0.1,
        ..Default::default()
    };
    println!("  training {key} …");
    let r = train(rt, manifest, &ctx.cfg, &opts)?;
    ctx.run_cache.insert(key, r.clone());
    Ok(r)
}

fn emit_curves(ctx: &Ctx, id: &str, title: &str,
               runs: &[(String, RunMetrics)]) -> Result<()> {
    let mut plot = LinePlot::new(title, "round", "test accuracy");
    let mut csv = String::from("framework,round,test_acc\n");
    for (name, run) in runs {
        let curve = run.accuracy_curve();
        plot.series(name, &curve);
        for (r, a) in &curve {
            csv.push_str(&format!("{name},{r},{a:.4}\n"));
        }
    }
    println!("{}", plot.render());
    ctx.save(&format!("{id}.csv"), &csv)?;
    ctx.save(&format!("{id}.txt"), &plot.render())
}

/// Fig. 4 — (a) accuracy vs rounds, (b) per-round latency bars, C=5,
/// HAM-like IID.
pub fn fig4(ctx: &mut Ctx) -> Result<()> {
    let rounds = if ctx.quick { 250 } else { 400 };
    let dataset = if ctx.quick { 1500 } else { 8000 };
    let mut runs = Vec::new();
    for (name, fw) in curve_frameworks() {
        let r = curve_run(ctx, "ham", true, &name, fw, 5, rounds, dataset)?;
        runs.push((name, r));
    }
    emit_curves(ctx, "fig4a", "Fig 4a: test accuracy (HAM-like, IID, C=5)",
                &runs)?;
    // (b) per-round latency from the §V model (first round's record).
    let items: Vec<(String, f64)> = runs
        .iter()
        .map(|(name, run)| (name.clone(), run.rounds[0].sim_latency))
        .collect();
    let chart =
        bar_chart("Fig 4b: per-round latency (s), C=5", &items, "s");
    println!("{chart}");
    let mut t = Table::new("fig4b").header(&["framework", "latency_s"]);
    for (n, v) in &items {
        t.row(&[n.clone(), format!("{v:.4}")]);
    }
    ctx.save("fig4b.csv", &t.to_csv())?;
    ctx.save("fig4b.txt", &chart)
}

fn accuracy_fig(ctx: &mut Ctx, id: &str, family: &str) -> Result<()> {
    let rounds = if ctx.quick { 250 } else { 400 };
    let dataset = if ctx.quick { 1500 } else { 8000 };
    // quick mode drops vanilla SL from the non-IID half (it is by far the
    // slowest to run and its curve shape is established by the IID half).
    for (suffix, iid) in [("a", true), ("b", false)] {
        let mut runs = Vec::new();
        for (name, fw) in curve_frameworks() {
            if ctx.quick && !iid && matches!(fw, Framework::VanillaSl) {
                continue;
            }
            let r =
                curve_run(ctx, family, iid, &name, fw, 5, rounds, dataset)?;
            runs.push((name, r));
        }
        emit_curves(
            ctx,
            &format!("{id}{suffix}"),
            &format!(
                "{id}{suffix}: {family}-like, {} (C=5)",
                if iid { "IID" } else { "non-IID" }
            ),
            &runs,
        )?;
    }
    Ok(())
}

/// Fig. 7 — MNIST-like accuracy curves, IID (a) and non-IID (b).
pub fn fig7(ctx: &mut Ctx) -> Result<()> {
    accuracy_fig(ctx, "fig7", "mnist")
}

/// CI accuracy smoke: a short fig4-style run (all five frameworks, tiny
/// budget) that asserts finite loss/accuracy and emits the curve CSV —
/// the guard that keeps the training path from regressing to all-skip.
pub fn accuracy_smoke(ctx: &mut Ctx) -> Result<()> {
    let (rounds, dataset, clients) = (24, 480, 2);
    let mut runs = Vec::new();
    for (name, fw) in curve_frameworks() {
        let opts = TrainerOptions {
            family: "mnist".into(),
            framework: fw,
            n_clients: clients,
            rounds,
            eval_every: 8,
            dataset_size: dataset,
            test_size: 256,
            eta_c: 0.1,
            eta_s: 0.1,
            ..Default::default()
        };
        println!("  smoke-training {name} …");
        let run = train(ctx.runtime()?, ctx.manifest()?, &ctx.cfg, &opts)?;
        if run.rounds.iter().any(|r| !r.loss.is_finite()) {
            return Err(crate::error::Error::Runtime(format!(
                "accuracy smoke: {name} produced a non-finite loss"
            )));
        }
        let evaluated: Vec<f64> = run
            .rounds
            .iter()
            .filter_map(|r| r.test_acc)
            .collect();
        if evaluated.is_empty()
            || evaluated.iter().any(|a| !a.is_finite())
        {
            return Err(crate::error::Error::Runtime(format!(
                "accuracy smoke: {name} produced no finite test accuracy"
            )));
        }
        runs.push((name, run));
    }
    emit_curves(ctx, "accuracy_smoke",
                "Accuracy smoke: test accuracy (MNIST-like, IID, C=2)",
                &runs)
}

/// Fig. 8 — HAM-like accuracy curves, IID (a) and non-IID (b).
pub fn fig8(ctx: &mut Ctx) -> Result<()> {
    accuracy_fig(ctx, "fig8", "ham")
}
