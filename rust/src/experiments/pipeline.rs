//! fig_pipeline — barrier vs pipelined round latency across cut layers
//! and client counts (repo extension; no paper analogue).
//!
//! Each cell draws its own deployment from a cell-local seed, prices a
//! uniform-power decision at the cell's cut (deterministic — no solver
//! failures to drop), and runs the *same* realized rates through the
//! timeline engine in both modes. The grid fans across cores via
//! [`par::parallel_map`], bit-identical to the serial loop for any
//! thread count. Besides the figure itself, the run re-checks the
//! engine's core invariant on every cell: `pipelined ≤ barrier`, with a
//! hard error (not a silent row) on violation.

use crate::channel::{ChannelRealization, Deployment};
use crate::config::NetworkConfig;
use crate::coordinator::resnet18_cut_for_splitnet;
use crate::error::{Error, Result};
use crate::latency::frameworks::Framework;
use crate::latency::LatencyInputs;
use crate::optim::{baselines, Problem};
use crate::profile::resnet18;
use crate::timeline::{simulate, Mode};
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::{LinePlot, Table};

use super::Ctx;

/// One (cut × C × seed) cell.
#[derive(Debug, Clone)]
struct PipelineCell {
    net: NetworkConfig,
    /// SplitNet cut 1..=4 (mapped onto the ResNet-18 Table-IV profile).
    splitnet_cut: usize,
    dep_seed: u64,
    batch: usize,
    phi: f64,
}

/// Evaluate one cell: (barrier seconds, pipelined seconds).
fn eval_cell(cell: &PipelineCell) -> (f64, f64) {
    let profile = resnet18::profile_static();
    let mut rng = Rng::new(cell.dep_seed);
    let dep = Deployment::generate(&cell.net, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cell.net,
        profile,
        dep: &dep,
        ch: &ch,
        batch: cell.batch,
        phi: cell.phi,
    };
    let cut = resnet18_cut_for_splitnet(cell.splitnet_cut);
    let d = baselines::uniform_decision(&prob, cut);
    let (up, dn, bc) = prob.rates(&d);
    let inp = LatencyInputs {
        profile,
        cut,
        batch: cell.batch,
        phi: cell.phi,
        f_server: cell.net.f_server,
        kappa_server: cell.net.kappa_server,
        kappa_client: cell.net.kappa_client,
        f_clients: dep.f_clients(),
        uplink: &up,
        downlink: &dn,
        broadcast: bc,
        uplink_comp: cell.net.uplink_compression,
    };
    let fw = Framework::Epsl { phi: cell.phi };
    (
        simulate(fw, &inp, Mode::Barrier).total,
        simulate(fw, &inp, Mode::Pipelined).total,
    )
}

/// fig_pipeline — what does phase overlap buy, per cut and client count?
pub fn fig_pipeline(ctx: &mut Ctx) -> Result<()> {
    let cuts: [usize; 4] = [1, 2, 3, 4];
    let sweep_c: Vec<usize> =
        if ctx.quick { vec![1, 4, 8] } else { vec![1, 4, 8, 16, 32] };
    let seeds: u64 = if ctx.quick { 3 } else { 10 };

    let mut cells = Vec::new();
    for &cut in &cuts {
        for &c in &sweep_c {
            let net = ctx.cfg.net.clone().with_clients(c);
            for s in 0..seeds {
                cells.push(PipelineCell {
                    net: net.clone(),
                    splitnet_cut: cut,
                    dep_seed: 0xF1DE + s,
                    batch: ctx.cfg.train.batch,
                    phi: ctx.cfg.train.phi,
                });
            }
        }
    }
    let outs = par::parallel_map(&cells, par::max_threads(), |_, cell| {
        eval_cell(cell)
    });
    // The engine's invariant is a hard gate, checked on every cell.
    for (cell, &(bar, pipe)) in cells.iter().zip(&outs) {
        if !bar.is_finite() || !pipe.is_finite() || pipe > bar {
            return Err(Error::Runtime(format!(
                "timeline invariant violated: pipelined {pipe} vs barrier \
                 {bar} (cut {}, C {})",
                cell.splitnet_cut, cell.net.n_clients
            )));
        }
    }

    let mut t = Table::new("fig_pipeline").header(&[
        "cut", "C", "barrier (s)", "pipelined (s)", "saved (%)",
    ]);
    let mut plot = LinePlot::new(
        "fig_pipeline: latency saved by phase overlap",
        "clients C",
        "saved (%)",
    );
    let mut series: Vec<(String, Vec<(f64, f64)>)> = cuts
        .iter()
        .map(|cut| (format!("cut {cut}"), Vec::new()))
        .collect();
    // Consume in the exact construction order: cut-major, then C, with
    // one `seeds`-sized chunk per (cut, C) pair.
    let mut chunks = outs.chunks(seeds as usize);
    for (cut_i, &cut) in cuts.iter().enumerate() {
        for &c in &sweep_c {
            let chunk = chunks
                // audit:allow(R1, "the solve fan-out produced exactly one chunk per (cut, C) cell, in this same order")
                .next().expect("fig_pipeline cell grid mismatch");
            let bars: Vec<f64> = chunk.iter().map(|(b, _)| *b).collect();
            let pipes: Vec<f64> = chunk.iter().map(|(_, p)| *p).collect();
            let (mb, mp) = (mean(&bars), mean(&pipes));
            let saved = 100.0 * (1.0 - mp / mb);
            series[cut_i].1.push((c as f64, saved));
            t.row(&[
                cut.to_string(),
                c.to_string(),
                format!("{mb:.3}"),
                format!("{mp:.3}"),
                format!("{saved:.1}"),
            ]);
        }
    }
    for (name, pts) in &series {
        plot.series(name, pts);
    }
    println!("{}", plot.render());
    println!("{}", t.render());
    ctx.save("fig_pipeline.csv", &t.to_csv())?;
    ctx.save("fig_pipeline.txt", &plot.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_hold_the_invariant_and_gain_under_heterogeneity() {
        let net = NetworkConfig::default().with_clients(4);
        for cut in 1..=4usize {
            let cell = PipelineCell {
                net: net.clone(),
                splitnet_cut: cut,
                dep_seed: 0xF1DE,
                batch: 64,
                phi: 0.5,
            };
            let (bar, pipe) = eval_cell(&cell);
            assert!(bar > 0.0 && pipe > 0.0);
            assert!(pipe <= bar, "cut {cut}: {pipe} > {bar}");
            // The Table-III deployment draw is heterogeneous: strict gain.
            assert!(pipe < bar, "cut {cut}: no overlap gain");
        }
    }

    #[test]
    fn cell_eval_is_deterministic() {
        let cell = PipelineCell {
            net: NetworkConfig::default().with_clients(3),
            splitnet_cut: 2,
            dep_seed: 7,
            batch: 64,
            phi: 0.5,
        };
        let a = eval_cell(&cell);
        let b = eval_cell(&cell);
        assert_eq!(a.0.to_bits(), b.0.to_bits());
        assert_eq!(a.1.to_bits(), b.1.to_bits());
    }
}
