//! Descriptive statistics + tiny fits used by benches and experiments.

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Compute mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 when n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile via linear interpolation on the sorted copy. `q` in [0,100].
///
/// NaN entries are dropped before sorting: aggregate series can
/// legitimately carry NaN sentinels (e.g. `converged_accuracy` of a
/// never-evaluated run), and the previous `partial_cmp(..).unwrap()`
/// comparator panicked on them.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if s.is_empty() {
        return 0.0;
    }
    s.sort_by(f64::total_cmp);
    let rank = (q / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

/// Full summary in one pass over a copy. NaN sentinels are excluded from
/// every statistic (`n` reports the finite count), so summarizing a
/// metrics column that interleaves NaN (e.g. per-run converged accuracy
/// of never-evaluated runs) yields the summary of the defined points.
pub fn summarize(xs: &[f64]) -> Summary {
    let finite: Vec<f64> =
        xs.iter().copied().filter(|x| !x.is_nan()).collect();
    let xs = &finite[..];
    let n = xs.len();
    if n == 0 {
        return Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
            p50: 0.0,
            p90: 0.0,
            p99: 0.0,
        };
    }
    Summary {
        n,
        mean: mean(xs),
        std: std_dev(xs),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        p50: percentile(xs, 50.0),
        p90: percentile(xs, 90.0),
        p99: percentile(xs, 99.0),
    }
}

/// Least-squares line fit `y = a + b x`; returns (a, b).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx.abs() < 1e-300 || n < 2.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Exponential moving average smoothing (alpha in (0,1]).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

/// First index where the EMA-smoothed series crosses `target` from below
/// (for "rounds to reach target accuracy"); `None` if never.
pub fn rounds_to_target(series: &[f64], target: f64, alpha: f64) -> Option<usize> {
    ema(series, alpha).iter().position(|&v| v >= target)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summarize_empty_is_zeroed() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_ignores_nan_sentinels() {
        // Pre-fix this panicked in partial_cmp(..).unwrap(); a metrics
        // accuracy column looks exactly like this (NaN on non-eval rounds).
        let xs = [f64::NAN, 1.0, f64::NAN, 2.0, 3.0, 4.0, f64::NAN];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // All-NaN behaves like empty.
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
    }

    #[test]
    fn summarize_curve_with_nan_sentinels() {
        let curve = [0.1, f64::NAN, 0.3, f64::NAN, 0.5];
        let s = summarize(&curve);
        assert_eq!(s.n, 3, "NaN rounds must not count");
        assert!((s.mean - 0.3).abs() < 1e-12);
        assert_eq!(s.min, 0.1);
        assert_eq!(s.max, 0.5);
        assert!((s.p50 - 0.3).abs() < 1e-12);
        assert!(s.std.is_finite());
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.5 * x).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.5).abs() < 1e-9);
    }

    #[test]
    fn ema_converges_to_constant() {
        let xs = vec![5.0; 100];
        let e = ema(&xs, 0.2);
        assert!((e[99] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn rounds_to_target_finds_crossing() {
        let series: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let r = rounds_to_target(&series, 0.5, 1.0).unwrap();
        assert_eq!(r, 50);
        assert!(rounds_to_target(&series, 2.0, 1.0).is_none());
    }
}
