//! Deterministic fork-join parallelism on `std::thread::scope`.
//!
//! The offline registry ships no rayon, so the sweep engine gets its own
//! minimal work-stealing executor: an atomic cursor hands out item indices,
//! each worker writes its result into the item's dedicated slot, and the
//! caller receives results **in input order** — so a parallel map over
//! pure, per-item-seeded work is bit-identical to the serial loop it
//! replaces, regardless of thread count or scheduling.
//!
//! Thread count resolution: the `EPSL_THREADS` environment variable wins
//! (set `EPSL_THREADS=1` to force the serial path), otherwise
//! `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker-count default: `EPSL_THREADS` override or the machine's
/// available parallelism.
pub fn max_threads() -> usize {
    match std::env::var("EPSL_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    }
}

/// Order-preserving parallel map: `out[i] = f(i, &items[i])` for every
/// item, computed on up to `threads` scoped workers. `threads <= 1` runs
/// the plain serial loop (no thread machinery at all).
///
/// A panic in any worker propagates to the caller when the scope joins, so
/// test assertions inside `f` surface normally.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                // audit:allow(R1, "scope has joined and the cursor covered every index, so each slot holds Some; a worker panic would have propagated at join")
                .expect("parallel_map: every slot is filled before join")
        })
        .collect()
}

/// Parallel for-each over disjoint mutable chunks of `data`:
/// `f(chunk_index, chunk)` for every `chunk`-sized piece (last may be
/// shorter), on up to `threads` scoped workers. Because the chunks are
/// disjoint and `f` writes only its own chunk, the result is identical
/// to the serial loop for any thread count — the primitive under the
/// batched im2col / blocked-GEMM fan-out, where output rows partition
/// cleanly but must land in one shared buffer.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk: usize,
                                 threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "parallel_chunks_mut: chunk must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = data.len().div_ceil(chunk);
    let threads = threads.clamp(1, n_chunks);
    if threads == 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    // One shared work iterator: each (index, chunk) pair is handed to
    // exactly one worker. The guard is dropped before `f` runs (the lock
    // temporary dies at the end of the `let` statement), so workers
    // compute unlocked; no per-chunk allocation is involved.
    let work = Mutex::new(data.chunks_mut(chunk).enumerate());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let next =
                    work.lock().unwrap_or_else(|e| e.into_inner()).next();
                let Some((i, c)) = next else {
                    break;
                };
                f(i, c);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order_across_thread_counts() {
        let items: Vec<usize> = (0..97).collect();
        let expect: Vec<usize> = items.iter().map(|x| x * x + 1).collect();
        for threads in [1, 2, 4, 16] {
            let got = parallel_map(&items, threads, |i, &x| {
                assert_eq!(i, x);
                x * x + 1
            });
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let got: Vec<u32> = parallel_map(&[] as &[u32], 8, |_, &x| x);
        assert!(got.is_empty());
    }

    #[test]
    fn more_threads_than_items_is_fine() {
        let got = parallel_map(&[10u64, 20], 64, |_, &x| x + 1);
        assert_eq!(got, vec![11, 21]);
    }

    #[test]
    fn parallel_equals_serial_on_float_work() {
        // The determinism contract: per-item pure work gives bit-identical
        // results under any thread count.
        let items: Vec<f64> = (0..64).map(|i| 0.1 + i as f64).collect();
        let work = |_: usize, &x: &f64| (x.sqrt().ln_1p() * 1e6).sin();
        let serial = parallel_map(&items, 1, work);
        let par = parallel_map(&items, 8, work);
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn chunks_mut_equals_serial_for_any_thread_count() {
        let serial: Vec<u64> = {
            let mut v = vec![0u64; 103];
            parallel_chunks_mut(&mut v, 8, 1, |i, c| {
                for (j, x) in c.iter_mut().enumerate() {
                    *x = (i * 1000 + j) as u64;
                }
            });
            v
        };
        for threads in [2, 4, 16] {
            let mut v = vec![0u64; 103];
            parallel_chunks_mut(&mut v, 8, threads, |i, c| {
                for (j, x) in c.iter_mut().enumerate() {
                    *x = (i * 1000 + j) as u64;
                }
            });
            assert_eq!(v, serial, "threads={threads}");
        }
    }

    #[test]
    fn chunks_mut_empty_and_short_tail() {
        let mut empty: Vec<u8> = vec![];
        parallel_chunks_mut(&mut empty, 4, 8, |_, _| panic!("no chunks"));
        let mut v = vec![0u8; 5];
        parallel_chunks_mut(&mut v, 4, 8, |i, c| {
            c.fill(i as u8 + 1);
        });
        assert_eq!(v, vec![1, 1, 1, 1, 2]);
    }
}
