//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are built with `harness = false` and call
//! [`Bencher::run`] per case: warmup, adaptive iteration-count calibration to
//! a target measurement time, then batched timing with summary statistics.
//! Results print as a table and can be appended to a log file for the
//! EXPERIMENTS.md §Perf records.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::{summarize, Summary};
use super::table::Table;

/// Monotonic wall-clock span measurement for *host-time* statistics:
/// backend compile/execute counters and the driver's wall-ms report.
///
/// The audit pass (R3, see `ANALYSIS.md`) confines `std::time` to this
/// module so simulated-latency paths can never read the host clock by
/// accident — everything that legitimately needs real elapsed time
/// starts a `WallTimer` instead of importing `Instant`.
#[derive(Debug, Clone, Copy)]
pub struct WallTimer(Instant);

impl WallTimer {
    /// Start measuring now.
    pub fn start() -> Self {
        WallTimer(Instant::now())
    }

    /// Seconds elapsed since `start`.
    pub fn elapsed_seconds(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed since `start`.
    pub fn elapsed_millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// One benchmark's measured result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Nanoseconds per iteration.
    pub summary: Summary,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn ns_per_iter(&self) -> f64 {
        self.summary.mean
    }

    /// Human units.
    pub fn pretty_time(&self) -> String {
        format_ns(self.summary.mean)
    }
}

/// Format nanoseconds with adaptive units.
pub fn format_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with fixed warmup / measurement budgets.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            max_samples: 50,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for slow end-to-end cases.
    pub fn slow() -> Self {
        Bencher {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(2000),
            max_samples: 12,
            results: Vec::new(),
        }
    }

    /// Tiny budgets for CI smoke runs (`cargo bench -- --test` just checks
    /// the bench binaries execute, not the numbers).
    pub fn smoke() -> Self {
        Bencher {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(40),
            max_samples: 4,
            results: Vec::new(),
        }
    }

    /// Run one case. `f` returns a value that is black-boxed.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F)
        -> &BenchResult {
        // Warmup + calibration: how many iters fit in ~1/10 of measure?
        let wstart = Instant::now();
        let mut calib_iters = 0u64;
        while wstart.elapsed() < self.warmup {
            black_box(f());
            calib_iters += 1;
        }
        let per_iter =
            self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let target_sample_ns =
            self.measure.as_nanos() as f64 / self.max_samples as f64;
        let iters_per_sample =
            ((target_sample_ns / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.max_samples);
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure && samples.len() < self.max_samples
        {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64;
            samples.push(dt / iters_per_sample as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            summary: summarize(&samples),
            iters_per_sample,
            samples: samples.len(),
        };
        println!(
            "bench {:<44} {:>12}/iter  (p50 {:>12}, n={})",
            res.name,
            res.pretty_time(),
            format_ns(res.summary.p50),
            res.samples
        );
        self.results.push(res);
        // audit:allow(R1, "a result was pushed on the previous line, so last() is Some")
        self.results.last().unwrap()
    }

    /// Render all results as a table.
    pub fn report(&self) -> String {
        let mut t = Table::new("benchmark results")
            .header(&["name", "mean", "p50", "p90", "min", "samples"]);
        for r in &self.results {
            t.row(&[
                r.name.clone(),
                format_ns(r.summary.mean),
                format_ns(r.summary.p50),
                format_ns(r.summary.p90),
                format_ns(r.summary.min),
                r.samples.to_string(),
            ]);
        }
        t.render()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write every result as the PERF.md perf-trajectory JSON record
    /// (`[{"name", "ns_per_iter", "p50_ns", "samples"}, ...]`) when the
    /// `BENCH_JSON` environment variable names a path. The single home
    /// for the record format — every bench binary calls this, and records
    /// already in the file are **merged by name** (same-name entries
    /// replaced, others kept), so `BENCH_JSON=x cargo bench` accumulates
    /// across bench binaries instead of each clobbering the last.
    pub fn write_bench_json_if_requested(&self) {
        let Ok(path) = std::env::var("BENCH_JSON") else {
            return;
        };
        use super::json::Json;
        use std::collections::BTreeMap;
        // Existing records (if the file parses as the expected array),
        // keyed by name and kept in insertion order.
        let mut order: Vec<String> = Vec::new();
        let mut by_name: BTreeMap<String, Json> = BTreeMap::new();
        if let Ok(prev) = std::fs::read_to_string(&path) {
            if let Ok(doc) = Json::parse(&prev) {
                if let Some(arr) = doc.as_arr() {
                    for rec in arr {
                        if let Some(name) =
                            rec.get("name").and_then(Json::as_str)
                        {
                            order.push(name.to_string());
                            by_name.insert(name.to_string(), rec.clone());
                        }
                    }
                }
            }
        }
        for r in &self.results {
            let mut obj = BTreeMap::new();
            obj.insert("name".to_string(), Json::Str(r.name.clone()));
            obj.insert("ns_per_iter".to_string(), Json::Num(r.summary.mean));
            obj.insert("p50_ns".to_string(), Json::Num(r.summary.p50));
            obj.insert("samples".to_string(), Json::Num(r.samples as f64));
            if by_name.insert(r.name.clone(), Json::Obj(obj)).is_none() {
                order.push(r.name.clone());
            }
        }
        let records: Vec<Json> = order
            .iter()
            .filter_map(|name| by_name.get(name).cloned())
            .collect();
        let n = records.len();
        std::fs::write(&path, Json::Arr(records).to_string_pretty())
            // audit:allow(R1, "bench-record tooling path: an unwritable BENCH_JSON target should abort the bench run loudly")
            .expect("write BENCH_JSON");
        println!("wrote {path} ({n} records)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_cheap_op() {
        let mut b = Bencher {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(50),
            max_samples: 10,
            results: Vec::new(),
        };
        let r = b.run("add", || black_box(1u64) + black_box(2u64));
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.mean < 1e6, "1 add should be << 1ms");
    }

    #[test]
    fn report_contains_names() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_samples: 5,
            results: Vec::new(),
        };
        b.run("case_a", || 1 + 1);
        b.run("case_b", || 2 + 2);
        let rep = b.report();
        assert!(rep.contains("case_a") && rep.contains("case_b"));
    }

    #[test]
    fn format_units() {
        assert!(format_ns(5.0).contains("ns"));
        assert!(format_ns(5e4).contains("µs"));
        assert!(format_ns(5e7).contains("ms"));
        assert!(format_ns(5e9).contains(" s"));
    }
}
