//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256** seeded via splitmix64 — the standard pairing:
//! splitmix64 decorrelates arbitrary user seeds, xoshiro256** provides the
//! stream. All experiment randomness (channel fading, client placement,
//! dataset synthesis, mini-batch sampling) flows through this type so every
//! figure is reproducible from a single `u64` seed.

/// splitmix64 step — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Serializable snapshot of an [`Rng`]'s complete state: the four
/// xoshiro256** lanes plus the cached Box–Muller deviate. Restoring via
/// [`Rng::from_state`] continues the stream bit-exactly — the substrate
/// of session checkpoint/resume.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

/// xoshiro256** generator with distribution helpers.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller deviate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create from a user seed (splitmix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Snapshot the complete generator state (checkpointing).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, gauss_spare: self.gauss_spare }
    }

    /// Rebuild a generator from a snapshot; the restored stream is
    /// bit-identical to the original from the snapshot point on
    /// (including a pending cached Gaussian deviate).
    pub fn from_state(st: RngState) -> Rng {
        Rng { s: st.s, gauss_spare: st.gauss_spare }
    }

    /// Derive an independent child stream (for per-client / per-figure use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-ish rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick: unbiased enough for simulation use.
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Uniformly random point in a disc of radius `r` (client placement).
    pub fn in_disc(&mut self, r: f64) -> (f64, f64) {
        let radius = r * self.f64().sqrt();
        let theta = 2.0 * std::f64::consts::PI * self.f64();
        (radius * theta.cos(), radius * theta.sin())
    }

    /// Vector of `n` iid uniform(lo,hi) draws.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform(lo, hi)).collect()
    }
}

/// Central registry of every [`Rng::fork`] tag in the tree.
///
/// A fork tag is the identity of a derived RNG stream: two call sites
/// forking the same parent with the same tag get *correlated* streams,
/// which silently couples whatever randomness they drive (the bug
/// class behind the PR 8 seed-packing fix). Every tag therefore lives
/// here as a documented named constant — raw literals at call sites
/// are denied by audit rule R8 — and uniqueness is enforced twice:
/// at compile time by the `ALL`-array asserts below (independent of
/// the analyzer), and tree-wide by R8's registry self-checks.
///
/// Conventions:
/// * values are `u64`, unique, and ≥ `0x1000` — keeping tags out of
///   the small-integer range makes R8's raw-value collision scan
///   meaningful;
/// * names are `SCREAMING_SNAKE`, prefixed by the owning subsystem;
/// * every constant is mirrored in [`ALL`], which feeds the
///   compile-time asserts (the audit denies drift between the two).
pub mod streams {
    /// Base stream for per-round scenario dynamics
    /// (`scenario::engine`); parent of the churn/LoS/jitter substreams.
    pub const SCENARIO_DYNAMICS: u64 = 0xFEA7;
    /// Client churn (departure/arrival) draws.
    pub const SCENARIO_CHURN: u64 = 0xC42B;
    /// Line-of-sight blockage state flips.
    pub const SCENARIO_LOS: u64 = 0x105F;
    /// Per-round rate-jitter multipliers.
    pub const SCENARIO_JITTER: u64 = 0x717E;
    /// Base stream for the fault-injection plan (`scenario::faults`);
    /// parent of the per-fault-kind substreams.
    pub const FAULT_PLAN: u64 = 0xFA17;
    /// Client-crash fault draws.
    pub const FAULT_CRASH: u64 = 0xC8A5;
    /// Link-delay fault draws.
    pub const FAULT_DELAY: u64 = 0xDE1A;
    /// Activation-corruption fault draws.
    pub const FAULT_CORRUPT: u64 = 0xC077;
    /// Round-abort fault draws.
    pub const FAULT_ABORT: u64 = 0xAB07;

    /// Mirror of every registered tag, in declaration order. Feeds the
    /// compile-time uniqueness/floor asserts; audit rule R8 denies any
    /// drift between this array and the constants above.
    pub const ALL: [u64; 9] = [
        SCENARIO_DYNAMICS,
        SCENARIO_CHURN,
        SCENARIO_LOS,
        SCENARIO_JITTER,
        FAULT_PLAN,
        FAULT_CRASH,
        FAULT_DELAY,
        FAULT_CORRUPT,
        FAULT_ABORT,
    ];

    const fn all_distinct(xs: &[u64]) -> bool {
        let mut i = 0;
        while i < xs.len() {
            let mut j = i + 1;
            while j < xs.len() {
                if xs[i] == xs[j] {
                    return false;
                }
                j += 1;
            }
            i += 1;
        }
        true
    }

    const fn all_at_least(xs: &[u64], floor: u64) -> bool {
        let mut i = 0;
        while i < xs.len() {
            if xs[i] < floor {
                return false;
            }
            i += 1;
        }
        true
    }

    const _: () = assert!(
        all_distinct(&ALL),
        "duplicate rng stream tag: two fork sites would correlate"
    );
    const _: () = assert!(
        all_at_least(&ALL, 0x1000),
        "rng stream tags must stay out of the small-integer range"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_tags_unique_and_above_floor() {
        // Runtime mirror of the compile-time asserts, so a registry
        // regression shows up as a named test failure — independent of
        // epsl-audit's R8 checks.
        let all = streams::ALL;
        for (i, a) in all.iter().enumerate() {
            assert!(*a >= 0x1000, "tag {a:#x} below floor");
            for b in &all[i + 1..] {
                assert_ne!(a, b, "duplicate stream tag {a:#x}");
            }
        }
        assert_eq!(all.len(), 9);
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn state_roundtrip_is_bit_exact() {
        let mut a = Rng::new(42);
        // Burn an odd number of Gaussian draws so a spare is cached —
        // the snapshot must carry it or the restored stream shifts.
        for _ in 0..7 {
            a.gaussian();
        }
        a.next_u64();
        let st = a.state();
        assert!(st.gauss_spare.is_some(), "fixture must cache a spare");
        let mut b = Rng::from_state(st);
        for _ in 0..64 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.f64().to_bits(), b.f64().to_bits());
        }
    }

    #[test]
    fn fork_is_independent() {
        let mut a = Rng::new(1);
        let mut f1 = a.fork(7);
        let mut f2 = a.fork(7); // second fork advances parent -> differs
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::new(8);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count={c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(10);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
    }

    #[test]
    fn in_disc_within_radius() {
        let mut r = Rng::new(11);
        for _ in 0..1_000 {
            let (x, y) = r.in_disc(200.0);
            assert!(x * x + y * y <= 200.0 * 200.0 + 1e-6);
        }
    }
}
