//! Floating-point ordering helpers for the optimizer hot paths.
//!
//! The solvers (`optim::*`) constantly pick argmin/argmax over latencies,
//! gains, and objective values. Those quantities are finite by
//! construction — they come from finite channel rates, FLOP counts, and
//! payload sizes, with infeasible candidates filtered before comparison —
//! so `partial_cmp` cannot observe a NaN. Centralizing the comparison
//! here keeps the one `expect` documented in a single place instead of
//! two dozen `partial_cmp(..).unwrap()` call sites.
//!
//! Deliberately **not** `f64::total_cmp`: total order ranks `-0.0`
//! below `+0.0`, so swapping it in could flip which of two equal-cost
//! candidates an argmin picks and silently change bit-exact allocation
//! golden results. `cmp_finite` preserves the exact `partial_cmp`
//! semantics every call site shipped with.

use std::cmp::Ordering;

/// Compare two floats that are finite by construction.
///
/// Panics only if a caller violates the no-NaN contract, which the
/// optimizer input validation (`Problem::check_feasible`, evaluator
/// table construction) rules out.
#[inline]
pub fn cmp_finite(a: f64, b: f64) -> Ordering {
    // audit:allow(R1, "documented contract: optimizer objectives are finite by construction; NaN here is a solver bug worth a loud stop")
    a.partial_cmp(&b).expect("cmp_finite: NaN in optimizer objective")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_like_partial_cmp() {
        assert_eq!(cmp_finite(1.0, 2.0), Ordering::Less);
        assert_eq!(cmp_finite(2.0, 1.0), Ordering::Greater);
        assert_eq!(cmp_finite(3.5, 3.5), Ordering::Equal);
        // Signed zeros stay Equal (unlike total_cmp) — load-bearing for
        // bit-exact argmin tie-breaks.
        assert_eq!(cmp_finite(-0.0, 0.0), Ordering::Equal);
        assert_eq!(cmp_finite(f64::INFINITY, 1.0), Ordering::Greater);
    }

    #[test]
    #[should_panic(expected = "cmp_finite")]
    fn nan_is_a_loud_stop() {
        let _ = cmp_finite(f64::NAN, 0.0);
    }
}
