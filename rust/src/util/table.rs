//! ASCII tables, line plots, and bar charts for terminal figure rendering.
//!
//! Every `repro figures --id …` invocation emits both a CSV (machine) and an
//! ASCII rendering (human) built with these helpers, so the paper's figures
//! can be eyeballed straight from the terminal.

use std::fmt::Write as _;

/// A simple column-aligned ASCII table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), ..Default::default() }
    }

    pub fn header<S: ToString>(mut self, cols: &[S]) -> Self {
        self.header = cols.iter().map(|c| c.to_string()).collect();
        self
    }

    pub fn row<S: ToString>(&mut self, cols: &[S]) -> &mut Self {
        self.rows.push(cols.iter().map(|c| c.to_string()).collect());
        self
    }

    /// Row from f64s with fixed precision.
    pub fn row_f64(&mut self, label: &str, vals: &[f64], prec: usize) {
        let mut r = vec![label.to_string()];
        for v in vals {
            r.push(format!("{v:.prec$}"));
        }
        self.rows.push(r);
    }

    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        for r in std::iter::once(&self.header).chain(self.rows.iter()) {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |out: &mut String| {
            for w in &widths {
                out.push('+');
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        let emit = |out: &mut String, row: &[String]| {
            for (i, w) in widths.iter().enumerate() {
                let cell = row.get(i).map(String::as_str).unwrap_or("");
                let _ = write!(out, "| {cell:w$} ");
            }
            out.push_str("|\n");
        };
        line(&mut out);
        if !self.header.is_empty() {
            emit(&mut out, &self.header);
            line(&mut out);
        }
        for r in &self.rows {
            emit(&mut out, r);
        }
        line(&mut out);
        out
    }

    /// CSV rendering (header + rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        if !self.header.is_empty() {
            let _ = writeln!(
                out,
                "{}",
                self.header.iter().map(|c| esc(c)).collect::<Vec<_>>()
                    .join(",")
            );
        }
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }
}

/// Multi-series ASCII line plot on a character grid.
pub struct LinePlot {
    title: String,
    xlabel: String,
    ylabel: String,
    width: usize,
    height: usize,
    series: Vec<(String, Vec<(f64, f64)>)>,
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

impl LinePlot {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> Self {
        LinePlot {
            title: title.to_string(),
            xlabel: xlabel.to_string(),
            ylabel: ylabel.to_string(),
            width: 72,
            height: 20,
            series: Vec::new(),
        }
    }

    pub fn series(&mut self, name: &str, pts: &[(f64, f64)]) -> &mut Self {
        self.series.push((name.to_string(), pts.to_vec()));
        self
    }

    pub fn render(&self) -> String {
        let all: Vec<(f64, f64)> =
            self.series.iter().flat_map(|(_, p)| p.iter().cloned()).collect();
        if all.is_empty() {
            return format!("== {} == (no data)\n", self.title);
        }
        let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &all {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
        if (xmax - xmin).abs() < 1e-12 {
            xmax = xmin + 1.0;
        }
        if (ymax - ymin).abs() < 1e-12 {
            ymax = ymin + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, (_, pts)) in self.series.iter().enumerate() {
            let mark = MARKS[si % MARKS.len()];
            for &(x, y) in pts {
                let cx = ((x - xmin) / (xmax - xmin)
                    * (self.width - 1) as f64)
                    .round() as usize;
                let cy = ((y - ymin) / (ymax - ymin)
                    * (self.height - 1) as f64)
                    .round() as usize;
                let row = self.height - 1 - cy.min(self.height - 1);
                grid[row][cx.min(self.width - 1)] = mark;
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==  [y: {}]", self.title, self.ylabel);
        for (i, row) in grid.iter().enumerate() {
            let yv = ymax
                - (ymax - ymin) * i as f64 / (self.height - 1) as f64;
            let _ = writeln!(
                out,
                "{yv:>10.3} |{}",
                row.iter().collect::<String>()
            );
        }
        let _ = writeln!(
            out,
            "{:>10} +{}",
            "",
            "-".repeat(self.width)
        );
        let _ = writeln!(
            out,
            "{:>12}{:<.3}  ..  {:.3}  [x: {}]",
            "", xmin, xmax, self.xlabel
        );
        for (si, (name, _)) in self.series.iter().enumerate() {
            let _ = writeln!(out, "    {} {}", MARKS[si % MARKS.len()], name);
        }
        out
    }
}

/// Horizontal ASCII bar chart (used for Fig. 4b latency bars).
pub fn bar_chart(title: &str, items: &[(String, f64)], unit: &str) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    let maxv = items.iter().map(|(_, v)| *v).fold(0.0_f64, f64::max);
    let maxw = items.iter().map(|(n, _)| n.chars().count()).max().unwrap_or(0);
    for (name, v) in items {
        let bars = if maxv > 0.0 {
            ((v / maxv) * 46.0).round() as usize
        } else {
            0
        };
        let _ = writeln!(
            out,
            "{name:>maxw$} | {} {v:.3} {unit}",
            "#".repeat(bars)
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo").header(&["name", "v1", "v2"]);
        t.row(&["alpha", "1", "2"]);
        t.row_f64("beta", &[1.23456, 7.0], 2);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("alpha"));
        assert!(s.contains("1.23"));
        // every data line same width
        let lines: Vec<&str> =
            s.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(&["hello, world", "2"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"hello, world\""));
    }

    #[test]
    fn lineplot_renders_marks() {
        let mut p = LinePlot::new("t", "x", "y");
        p.series("s1", &[(0.0, 0.0), (1.0, 1.0)]);
        p.series("s2", &[(0.0, 1.0), (1.0, 0.0)]);
        let s = p.render();
        assert!(s.contains('*'));
        assert!(s.contains('o'));
        assert!(s.contains("s1"));
    }

    #[test]
    fn lineplot_empty_ok() {
        let p = LinePlot::new("t", "x", "y");
        assert!(p.render().contains("no data"));
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            "lat",
            &[("EPSL".into(), 1.0), ("PSL".into(), 2.0)],
            "s",
        );
        assert!(s.contains("EPSL"));
        let epsl_bars =
            s.lines().find(|l| l.contains("EPSL")).unwrap().matches('#').count();
        let psl_bars =
            s.lines().find(|l| l.contains("PSL") && !l.contains("EPSL"))
                .unwrap().matches('#').count();
        assert!(psl_bars > epsl_bars);
    }
}
