//! Minimal JSON value, parser, and writer (no serde offline).
//!
//! Two consumers: the artifact `manifest.json` produced by `aot.py`
//! (parser), and experiment result files under `results/` (writer). The
//! parser covers the full JSON grammar minus exotic escapes; good enough for
//! machine-produced input, and strict about structure.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Io(format!(
                "trailing JSON at byte {} of {}",
                p.i,
                p.b.len()
            )));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with a path message (manifest loading).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Artifact(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        // Same contract as config::toml::Value::as_usize: only exact
        // non-negative integers (<= 2^53) read as counts — a fractional
        // or precision-lossy number is a type mismatch, not a value to
        // silently truncate.
        const MAX_EXACT_F64: f64 = 9_007_199_254_740_992.0;
        self.as_f64()
            .filter(|x| *x >= 0.0 && x.fract() == 0.0 && *x <= MAX_EXACT_F64)
            .map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of usize (shape vectors).
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| Error::Artifact("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Artifact("expected number".into()))
            })
            .collect()
    }

    // -- writer ---------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |n: usize| "  ".repeat(n);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Io(format!(
                "JSON: expected '{}' at byte {}",
                c as char, self.i
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err(Error::Io("JSON: unexpected end".into())),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Io(format!("JSON: bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| Error::Io(e.to_string()))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Io(format!("JSON number '{s}': {e}")))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Io("JSON: unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| {
                                    Error::Io("JSON: bad \\u".into())
                                })?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|e| Error::Io(e.to_string()))?,
                                16,
                            )
                            .map_err(|e| Error::Io(e.to_string()))?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(Error::Io("JSON: bad escape".into())),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| Error::Io(e.to_string()))?;
                    let ch = s.chars().next().ok_or_else(|| {
                        Error::Io("JSON: unterminated string".into())
                    })?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(Error::Io("JSON: bad array".into())),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(Error::Io("JSON: bad object".into())),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": [1, 2.5, -3], "b": {"c": "hi\n", "d": true},
                       "e": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().usize_vec().is_err(), true); // -3
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str().unwrap(),
            "hi\n"
        );
        let printed = v.to_string_pretty();
        let re = Json::parse(&printed).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_manifest_like() {
        let text = r#"{"version": 1, "shape": [2, 32, 16, 16, 8],
                      "dtype": "f32"}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            v.get("shape").unwrap().usize_vec().unwrap(),
            vec![2, 32, 16, 16, 8]
        );
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Ab""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Ab");
    }

    #[test]
    fn num_formats() {
        let v = Json::parse("[1e3, -2.5E-2, 0.125]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), 1000.0);
        assert!((a[1].as_f64().unwrap() + 0.025).abs() < 1e-12);
    }
}
