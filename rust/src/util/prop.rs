//! Property-based testing mini-framework (proptest is unavailable offline).
//!
//! A [`Gen`] wraps the crate PRNG with sized generators; [`check`] runs a
//! property over many random cases and, on failure, reports the seed so the
//! case replays deterministically. Shrinking is intentionally out of scope —
//! failures print the generating seed, which is enough to reproduce and
//! debug in a deterministic system.
//!
//! ```
//! use epsl::util::prop::{check, Gen};
//! check("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Random-case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Size hint: grows over the run so later cases are larger.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        self.rng.range(lo, hi_incl + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Positive f64 log-uniform across several orders of magnitude.
    pub fn f64_log(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.rng.uniform(lo.ln(), hi.ln())).exp()
    }

    /// A vector of length in [1, max_len] of values from `f`.
    pub fn vec_of<T>(&mut self, max_len: usize,
                     mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(1, max_len.max(1));
        (0..n).map(|_| f(self)).collect()
    }

    /// Simplex vector (non-negative, sums to 1) — dataset weights λ.
    pub fn simplex(&mut self, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> =
            (0..n).map(|_| self.rng.uniform(0.01, 1.0)).collect();
        let s: f64 = v.iter().sum();
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics (with the replay seed) if any
/// case panics. The base seed is derived from the property name so distinct
/// properties explore distinct streams but remain reproducible run-to-run.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let size = 2 + case * 30 / cases.max(1);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            // audit:allow(R1, "the property harness reports failures by panicking inside the test process; this is its one reporting channel")
            panic!(
                "property '{name}' failed on case {case} (replay seed \
                 {seed:#x}, size {size}):\n  {msg}"
            );
        }
    }
}

/// Replay one failing case by seed (debugging helper).
pub fn replay(seed: u64, size: usize, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen::new(seed, size);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("assoc", 100, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn simplex_sums_to_one() {
        check("simplex", 100, |g| {
            let n = g.usize_in(1, 20);
            let v = g.simplex(n);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x > 0.0));
        });
    }

    #[test]
    fn f64_log_spans_orders() {
        let mut g = Gen::new(1, 10);
        let mut small = false;
        let mut large = false;
        for _ in 0..1000 {
            let x = g.f64_log(1e-3, 1e3);
            assert!((1e-3..=1e3).contains(&x));
            small |= x < 1e-1;
            large |= x > 1e1;
        }
        assert!(small && large);
    }

    #[test]
    fn replay_is_deterministic() {
        let mut v1 = 0;
        replay(42, 5, |g| v1 = g.usize_in(0, 1000));
        let mut v2 = 0;
        replay(42, 5, |g| v2 = g.usize_in(0, 1000));
        assert_eq!(v1, v2);
    }
}
