//! From-scratch substrates for the offline build environment.
//!
//! The vendored registry only ships the `xla` crate's dependency closure, so
//! the usual ecosystem crates (rand, serde, criterion, proptest, clap…) are
//! unavailable. Everything the system needs is implemented here:
//!
//! - [`par`] — deterministic scoped-thread fork-join parallelism
//! - [`fp`] — float ordering for optimizer argmin/argmax hot paths
//! - [`rng`] — splitmix64 / xoshiro256** PRNG with distributions
//! - [`stats`] — descriptive statistics and simple fits
//! - [`json`] — minimal JSON writer *and* parser (for the artifact manifest)
//! - [`table`] — ASCII tables and terminal line/bar plots for figures
//! - [`bench`] — micro-benchmark harness behind `cargo bench`
//! - [`prop`] — property-based testing mini-framework

pub mod bench;
pub mod fp;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
