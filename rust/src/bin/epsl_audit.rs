//! `epsl-audit` — the in-tree determinism & safety static-analysis
//! pass. Walks `rust/src`, `rust/benches`, `rust/tests`, and
//! `examples`, enforces rules R1–R9 (see `ANALYSIS.md`), and exits
//! non-zero when any denied finding remains.
//!
//! ```text
//! cargo run --bin epsl-audit                 # warn-level R6, deny the rest
//! cargo run --bin epsl-audit -- --deny-all   # CI mode: everything denies
//! cargo run --bin epsl-audit -- --json       # machine-readable findings
//! cargo run --bin epsl-audit -- --sarif      # SARIF 2.1.0 log
//! cargo run --bin epsl-audit -- --root PATH  # audit another checkout
//! cargo run --bin epsl-audit -- --baseline audit-baseline.json
//!                                            # ratchet: frozen findings warn
//! cargo run --bin epsl-audit -- --write-baseline audit-baseline.json
//!                                            # freeze the current findings
//! ```

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use epsl::analysis::{
    audit_tree, severity, to_sarif, Baseline, RuleId, Severity,
};
use epsl::util::json::Json;

struct Options {
    deny_all: bool,
    json: bool,
    sarif: bool,
    root: PathBuf,
    baseline: Option<PathBuf>,
    write_baseline: Option<PathBuf>,
}

fn print_help() {
    println!("epsl-audit: static-analysis pass for the EPSL tree");
    println!();
    println!(
        "USAGE: epsl-audit [--deny-all] [--json | --sarif] [--root PATH]"
    );
    println!("                  [--baseline FILE] [--write-baseline FILE]");
    println!();
    println!("  --deny-all        treat advisory findings (R6) as errors");
    println!("  --json            emit findings as a JSON report");
    println!("  --sarif           emit findings as a SARIF 2.1.0 log");
    println!("  --root PATH       repo root to audit (default: this checkout)");
    println!("  --baseline FILE   ratchet: findings frozen in FILE only warn;");
    println!("                    fresh findings keep their severity");
    println!("  --write-baseline FILE  freeze the current findings to FILE");
    println!();
    println!("RULES:");
    for rule in RuleId::ALL {
        println!("  {rule} {:<20} {}", rule.name(), rule.summary());
    }
    println!();
    println!("Suppress a reviewed site with a trailing or preceding");
    println!("comment: // audit:allow(R<n>, \"reason\") — but keep it live:");
    println!("a suppression whose rule no longer fires is an R9 finding.");
}

fn default_root() -> PathBuf {
    // The crate manifest lives in rust/; the audited tree is its parent.
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    match manifest.parent() {
        Some(p) => p.to_path_buf(),
        None => manifest,
    }
}

fn parse_args(args: &[String]) -> Result<Option<Options>, String> {
    let mut opts = Options {
        deny_all: false,
        json: false,
        sarif: false,
        root: default_root(),
        baseline: None,
        write_baseline: None,
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--deny-all" => opts.deny_all = true,
            "--json" => opts.json = true,
            "--sarif" => opts.sarif = true,
            "--root" => {
                i += 1;
                let path = args
                    .get(i)
                    .ok_or_else(|| "--root requires a path".to_string())?;
                opts.root = PathBuf::from(path);
            }
            "--baseline" => {
                i += 1;
                let path = args
                    .get(i)
                    .ok_or_else(|| "--baseline requires a file".to_string())?;
                opts.baseline = Some(PathBuf::from(path));
            }
            "--write-baseline" => {
                i += 1;
                let path = args.get(i).ok_or_else(|| {
                    "--write-baseline requires a file".to_string()
                })?;
                opts.write_baseline = Some(PathBuf::from(path));
            }
            "--help" | "-h" => return Ok(None),
            other => {
                return Err(format!(
                    "unknown argument '{other}' (try --help)"
                ))
            }
        }
        i += 1;
    }
    if opts.json && opts.sarif {
        return Err("--json and --sarif are mutually exclusive".to_string());
    }
    Ok(Some(opts))
}

fn run(opts: &Options) -> Result<ExitCode, epsl::error::Error> {
    let report = audit_tree(&opts.root)?;

    if let Some(path) = &opts.write_baseline {
        let base = Baseline::from_findings(&report.findings);
        fs::write(path, base.to_json().to_string_pretty() + "\n").map_err(
            |e| {
                epsl::error::Error::Io(format!(
                    "write {}: {e}",
                    path.display()
                ))
            },
        )?;
        println!(
            "audit: baseline with {} entry(ies) written to {}",
            base.entries.len(),
            path.display()
        );
        return Ok(ExitCode::SUCCESS);
    }

    let baseline = match &opts.baseline {
        Some(path) => {
            let text = fs::read_to_string(path).map_err(|e| {
                epsl::error::Error::Io(format!(
                    "read {}: {e}",
                    path.display()
                ))
            })?;
            Some(Baseline::parse(&text)?)
        }
        None => None,
    };
    let (baselined, fresh) = match &baseline {
        Some(b) => b.partition(&report.findings),
        None => (Vec::new(), report.findings.clone()),
    };

    let mut denied = 0usize;
    let mut warned = baselined.len();
    for f in &fresh {
        match severity(f.rule, opts.deny_all) {
            Severity::Deny => denied += 1,
            Severity::Warn => warned += 1,
        }
    }
    let stale = report.stale_suppressions();

    if opts.sarif {
        println!(
            "{}",
            to_sarif(&fresh, &baselined, opts.deny_all).to_string_pretty()
        );
    } else if opts.json {
        let mut obj = BTreeMap::new();
        obj.insert(
            "root".to_string(),
            Json::Str(opts.root.display().to_string()),
        );
        obj.insert("files_scanned".to_string(),
                   Json::Num(report.files_scanned as f64));
        obj.insert("suppressed".to_string(),
                   Json::Num(report.suppressed as f64));
        obj.insert("denied".to_string(), Json::Num(denied as f64));
        obj.insert("warned".to_string(), Json::Num(warned as f64));
        obj.insert("baselined".to_string(), Json::Num(baselined.len() as f64));
        obj.insert("stale_suppressions".to_string(),
                   Json::Num(stale as f64));
        let render = |f: &epsl::analysis::Finding, demoted: bool| {
            let mut m = BTreeMap::new();
            m.insert("path".to_string(), Json::Str(f.path.clone()));
            m.insert("line".to_string(), Json::Num(f.line as f64));
            m.insert("rule".to_string(), Json::Str(f.rule.to_string()));
            m.insert("name".to_string(),
                     Json::Str(f.rule.name().to_string()));
            m.insert("token".to_string(), Json::Str(f.token.clone()));
            m.insert("snippet".to_string(), Json::Str(f.snippet.clone()));
            let sev = if demoted {
                "warn"
            } else {
                match severity(f.rule, opts.deny_all) {
                    Severity::Deny => "deny",
                    Severity::Warn => "warn",
                }
            };
            m.insert("severity".to_string(), Json::Str(sev.to_string()));
            m.insert("baselined".to_string(), Json::Bool(demoted));
            Json::Obj(m)
        };
        let mut findings: Vec<Json> =
            fresh.iter().map(|f| render(f, false)).collect();
        findings.extend(baselined.iter().map(|f| render(f, true)));
        obj.insert("findings".to_string(), Json::Arr(findings));
        println!("{}", Json::Obj(obj).to_string_pretty());
    } else {
        for (set, demoted) in [(&fresh, false), (&baselined, true)] {
            for f in set.iter() {
                let sev = if demoted {
                    "warn (baselined)"
                } else {
                    match severity(f.rule, opts.deny_all) {
                        Severity::Deny => "deny",
                        Severity::Warn => "warn",
                    }
                };
                println!(
                    "{}:{}: {sev} {} ({}) [{}] {}",
                    f.path,
                    f.line,
                    f.rule,
                    f.rule.name(),
                    f.token,
                    f.snippet
                );
            }
        }
        println!(
            "audit: {} file(s) scanned, {} finding(s) ({} denied, {} warned, \
             {} baselined), {} suppression(s) honored, stale-suppressions: {}",
            report.files_scanned,
            report.findings.len(),
            denied,
            warned,
            baselined.len(),
            report.suppressed,
            stale
        );
    }
    Ok(if denied > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    })
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = match parse_args(&args) {
        Ok(Some(opts)) => opts,
        Ok(None) => {
            print_help();
            return ExitCode::SUCCESS;
        }
        Err(msg) => {
            eprintln!("epsl-audit: {msg}");
            return ExitCode::from(2);
        }
    };
    if !Path::new(&opts.root).is_dir() {
        eprintln!(
            "epsl-audit: root '{}' is not a directory",
            opts.root.display()
        );
        return ExitCode::from(2);
    }
    match run(&opts) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("epsl-audit: {e}");
            ExitCode::from(2)
        }
    }
}
