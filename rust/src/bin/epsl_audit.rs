//! `epsl-audit` — the in-tree determinism & safety static-analysis
//! pass. Walks `rust/src`, `rust/benches`, `rust/tests`, and
//! `examples`, enforces rules R1–R6 (see `ANALYSIS.md`), and exits
//! non-zero when any denied finding remains.
//!
//! ```text
//! cargo run --bin epsl-audit                 # warn-level R6, deny R1–R5
//! cargo run --bin epsl-audit -- --deny-all   # CI mode: everything denies
//! cargo run --bin epsl-audit -- --json       # machine-readable findings
//! cargo run --bin epsl-audit -- --root PATH  # audit another checkout
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use epsl::analysis::{audit_tree, severity, RuleId, Severity};
use epsl::util::json::Json;

struct Options {
    deny_all: bool,
    json: bool,
    root: PathBuf,
}

fn print_help() {
    println!("epsl-audit: static-analysis pass for the EPSL tree");
    println!();
    println!("USAGE: epsl-audit [--deny-all] [--json] [--root PATH]");
    println!();
    println!("  --deny-all   treat advisory findings (R6) as errors");
    println!("  --json       emit findings as a JSON report");
    println!("  --root PATH  repo root to audit (default: this checkout)");
    println!();
    println!("RULES:");
    for rule in RuleId::ALL {
        println!("  {rule} {:<20} {}", rule.name(), rule.summary());
    }
    println!();
    println!("Suppress a reviewed site with a trailing or preceding");
    println!("comment: // audit:allow(R<n>, \"reason\")");
}

fn default_root() -> PathBuf {
    // The crate manifest lives in rust/; the audited tree is its parent.
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    match manifest.parent() {
        Some(p) => p.to_path_buf(),
        None => manifest,
    }
}

fn parse_args(args: &[String]) -> Result<Option<Options>, String> {
    let mut opts = Options {
        deny_all: false,
        json: false,
        root: default_root(),
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--deny-all" => opts.deny_all = true,
            "--json" => opts.json = true,
            "--root" => {
                i += 1;
                let path = args
                    .get(i)
                    .ok_or_else(|| "--root requires a path".to_string())?;
                opts.root = PathBuf::from(path);
            }
            "--help" | "-h" => return Ok(None),
            other => {
                return Err(format!(
                    "unknown argument '{other}' (try --help)"
                ))
            }
        }
        i += 1;
    }
    Ok(Some(opts))
}

fn run(opts: &Options) -> Result<ExitCode, epsl::error::Error> {
    let report = audit_tree(&opts.root)?;
    let mut denied = 0usize;
    let mut warned = 0usize;
    for f in &report.findings {
        match severity(f.rule, opts.deny_all) {
            Severity::Deny => denied += 1,
            Severity::Warn => warned += 1,
        }
    }
    if opts.json {
        let mut obj = BTreeMap::new();
        obj.insert(
            "root".to_string(),
            Json::Str(opts.root.display().to_string()),
        );
        obj.insert("files_scanned".to_string(),
                   Json::Num(report.files_scanned as f64));
        obj.insert("suppressed".to_string(),
                   Json::Num(report.suppressed as f64));
        obj.insert("denied".to_string(), Json::Num(denied as f64));
        obj.insert("warned".to_string(), Json::Num(warned as f64));
        let findings: Vec<Json> = report
            .findings
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert("path".to_string(), Json::Str(f.path.clone()));
                m.insert("line".to_string(), Json::Num(f.line as f64));
                m.insert("rule".to_string(), Json::Str(f.rule.to_string()));
                m.insert("name".to_string(),
                         Json::Str(f.rule.name().to_string()));
                m.insert("token".to_string(), Json::Str(f.token.clone()));
                m.insert("snippet".to_string(), Json::Str(f.snippet.clone()));
                let sev = match severity(f.rule, opts.deny_all) {
                    Severity::Deny => "deny",
                    Severity::Warn => "warn",
                };
                m.insert("severity".to_string(), Json::Str(sev.to_string()));
                Json::Obj(m)
            })
            .collect();
        obj.insert("findings".to_string(), Json::Arr(findings));
        println!("{}", Json::Obj(obj).to_string_pretty());
    } else {
        for f in &report.findings {
            let sev = match severity(f.rule, opts.deny_all) {
                Severity::Deny => "deny",
                Severity::Warn => "warn",
            };
            println!(
                "{}:{}: {sev} {} ({}) [{}] {}",
                f.path,
                f.line,
                f.rule,
                f.rule.name(),
                f.token,
                f.snippet
            );
        }
        println!(
            "audit: {} file(s) scanned, {} finding(s) ({} denied, {} warned), \
             {} suppression(s) honored",
            report.files_scanned,
            report.findings.len(),
            denied,
            warned,
            report.suppressed
        );
    }
    Ok(if denied > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    })
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = match parse_args(&args) {
        Ok(Some(opts)) => opts,
        Ok(None) => {
            print_help();
            return ExitCode::SUCCESS;
        }
        Err(msg) => {
            eprintln!("epsl-audit: {msg}");
            return ExitCode::from(2);
        }
    };
    if !Path::new(&opts.root).is_dir() {
        eprintln!(
            "epsl-audit: root '{}' is not a directory",
            opts.root.display()
        );
        return ExitCode::from(2);
    }
    match run(&opts) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("epsl-audit: {e}");
            ExitCode::from(2)
        }
    }
}
