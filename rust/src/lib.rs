//! # EPSL — Efficient Parallel Split Learning over wireless edge networks
//!
//! A from-scratch reproduction of Lin et al., *"Efficient Parallel Split
//! Learning over Resource-constrained Wireless Edge Networks"* (2023), as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the split-learning coordinator — round
//!   orchestration across client workers and the edge server, the wireless
//!   channel simulator, the per-round latency model (paper eqs. 13–23), the
//!   joint subchannel/power/cut-layer optimizer (Algorithms 2–3, problems
//!   P1–P4), and the experiment harness that regenerates every table and
//!   figure in the paper's evaluation.
//! - **L2 (python/compile/model.py)**: the split model's forward/backward
//!   graphs, AOT-lowered to HLO text at build time.
//! - **L1 (python/compile/kernels/)**: the EPSL last-layer
//!   gradient-aggregation Pallas kernel embedded in those graphs.
//!
//! Python never runs at training time: [`runtime`] loads the AOT artifacts
//! through the PJRT C API and the whole training loop is rust-native.
//!
//! ## Crate map
//!
//! | module | contents |
//! |---|---|
//! | [`util`] | substrates built from scratch for the offline environment: PRNG, stats, JSON, ASCII tables/plots, micro-bench + property-test harnesses |
//! | [`config`] | typed experiment configuration (paper Table III defaults), TOML-subset parser, CLI |
//! | [`profile`] | NN layer profiles: FLOPs ρ/ϖ and payloads ψ/χ — the paper's exact ResNet-18 Table IV plus the trainable SplitNet |
//! | [`channel`] | mmWave wireless model: path loss, shadowing, subchannels, link rates (eqs. 14, 18, 20) |
//! | [`latency`] | the seven per-stage latencies and the round total (eqs. 13–23) for EPSL and every baseline framework |
//! | [`timeline`] | event-timeline round engine: a deterministic discrete-event simulator over typed events; `barrier` mode reproduces eq. 23 bit-identically, `pipelined` mode overlaps phases per client/link |
//! | [`optim`] | the resource-management solver: greedy subchannel allocation (Alg. 2), convex power control (P2), cut-layer B&B MILP (P3), closed-form LP (P4), BCD (Alg. 3), baselines a–d |
//! | [`data`] | synthetic datasets + IID / non-IID partitioners |
//! | [`runtime`] | the execution-backend seam: PJRT execution of the AOT artifacts (HLO text → compile → execute) and the pure-Rust native backend (`runtime::native`) that implements the same entry-point contract on host f32 buffers — auto-selected when artifacts are absent |
//! | [`coordinator`] | the training system: leader + client workers, full EPSL/PSL/SFL/vanilla-SL drivers |
//! | [`scenario`] | multi-round network dynamics: block fading, LoS flips, compute jitter, churn, re-optimization policies |
//! | [`metrics`] | round records, curves, CSV emission |
//! | [`experiments`] | one registered generator per paper table/figure |
//! | [`analysis`] | in-tree static-analysis pass (`epsl-audit`): rules R1–R6 guarding the determinism/safety invariants above — see `ANALYSIS.md` |

pub mod analysis;
pub mod channel;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod latency;
pub mod metrics;
pub mod optim;
pub mod profile;
pub mod runtime;
pub mod scenario;
pub mod timeline;
pub mod util;

pub use error::{Error, Result};
