//! Minimal offline shim of the `anyhow` crate: just enough surface for the
//! `repro` CLI and the examples — a string-backed [`Error`] that any
//! `std::error::Error` converts into, the [`anyhow!`] and [`bail!`] macros,
//! and the [`Result`] alias. Deliberately mirrors the real crate's design
//! choice of *not* implementing `std::error::Error` for [`Error`] (that is
//! what makes the blanket `From` impl coherent).

use std::fmt;

/// A string-backed error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a single printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn takes_result() -> Result<()> {
        let io: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "boom"));
        io?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = takes_result().err().unwrap();
        assert!(format!("{e}").contains("boom"));
        assert!(format!("{e:?}").contains("boom"));
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let who = "x";
        let b = anyhow!("hello {who}");
        assert_eq!(b.to_string(), "hello x");
        let c = anyhow!("{} {}", 1, 2);
        assert_eq!(c.to_string(), "1 2");
        let msg = String::from("owned");
        let d = anyhow!(msg);
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("denied {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).err().unwrap().to_string(), "denied 7");
    }
}
