//! Offline stand-in for the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate wraps the PJRT C API; this container does not ship it, so
//! this stub keeps the dependency surface compiling with two behaviors:
//!
//! - **Host literals are fully functional.** [`Literal`] stores shape +
//!   dtype + bytes, so every host-side conversion helper (and its tests)
//!   works without PJRT.
//! - **Device entry points fail fast.** [`PjRtClient::cpu`] and
//!   [`HloModuleProto::from_text_file`] return a descriptive [`Error`], so
//!   training-backed code paths degrade to the same "artifacts unavailable"
//!   handling they already have for a fresh checkout.
//!
//! Swapping the real bindings back in is a one-line change in the
//! workspace manifest; no call site references anything stub-specific.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`'s role (stringly, Display-able).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }

    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT bindings are not vendored in this build \
             (offline xla stub)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla error: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Element dtypes used by the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 | ElementType::U32 => 4,
        }
    }
}

/// Rust native types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
}

/// A host-side literal: dtype + shape + raw bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType, shape: &[usize], data: &[u8],
    ) -> Result<Literal, Error> {
        let numel: usize = shape.iter().product();
        if numel * ty.byte_size() != data.len() {
            return Err(Error::msg(format!(
                "shape {shape:?} ({numel} x {}B) does not match {} data bytes",
                ty.byte_size(),
                data.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        if self.ty != T::TY {
            return Err(Error::msg(format!(
                "dtype mismatch: literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let n = self.element_count();
        let mut out: Vec<T> = Vec::with_capacity(n);
        // Safety: the constructor guarantees data.len() == n * size_of::<T>()
        // and the Vec allocation is aligned for T.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                self.data.len(),
            );
            out.set_len(n);
        }
        Ok(out)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::msg("empty literal has no first element"))
    }

    /// Decompose a tuple literal. Tuple literals only arise from PJRT
    /// execution, which the stub cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// PJRT client handle (always unavailable in the stub).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self, _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(
        &self, _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(
        _path: P,
    ) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
        unsafe {
            std::slice::from_raw_parts(
                v.as_ptr() as *const u8,
                std::mem::size_of_val(v),
            )
        }
    }

    #[test]
    fn literal_roundtrip() {
        let data = [1.5f32, -2.0, 0.25, 8.0];
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            bytes_of(&data),
        )
        .unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.5);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn bad_shape_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::U32,
            &[3],
            &[0u8; 8],
        )
        .is_err());
    }

    #[test]
    fn pjrt_is_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
    }
}
