"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/phi; the core signal of the compile path.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.phi_aggregate import (phi_aggregate, phi_aggregate_nd,
                                           sgd_update)
from compile.kernels.ref import (aggregation_mask, phi_aggregate_ref,
                                 sgd_update_ref)

settings.register_profile("ci", deadline=None, max_examples=40)
settings.load_profile("ci")


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _lam(key, c):
    raw = jax.random.uniform(key, (c,), jnp.float32, 0.05, 1.0)
    return raw / jnp.sum(raw)


# ---------------------------------------------------------------------------
# phi_aggregate vs ref
# ---------------------------------------------------------------------------


@given(
    c=st.integers(1, 12),
    b=st.integers(1, 48),
    q=st.integers(1, 700),
    phi=st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_phi_aggregate_matches_ref(c, b, q, phi, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    z = _rand(k1, (c, b, q), jnp.float32)
    lam = _lam(k2, c)
    mask = aggregation_mask(phi, b)
    out = phi_aggregate(z, lam, mask)
    ref = phi_aggregate_ref(z, lam, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


@given(
    c=st.integers(1, 6),
    b=st.integers(1, 16),
    q=st.integers(1, 200),
    tile=st.sampled_from([1, 7, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_phi_aggregate_tile_invariance(c, b, q, tile, seed):
    """Output must not depend on the feature-tile split."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    z = _rand(k1, (c, b, q), jnp.float32)
    lam = _lam(k2, c)
    mask = aggregation_mask(0.5, b)
    a = phi_aggregate(z, lam, mask, tile_q=tile)
    bfull = phi_aggregate(z, lam, mask, tile_q=q)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bfull), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_phi_aggregate_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    z = _rand(k1, (4, 8, 33), dtype)
    lam = _lam(k2, 4)
    mask = aggregation_mask(0.5, 8)
    out = phi_aggregate(z, lam, mask)
    ref = phi_aggregate_ref(z, lam, mask)
    assert out.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol,
        rtol=tol)


def test_phi_zero_is_identity():
    """phi=0 -> EPSL degenerates to PSL: the kernel must be the identity."""
    key = jax.random.PRNGKey(1)
    z = _rand(key, (5, 16, 40), jnp.float32)
    lam = _lam(key, 5)
    out = phi_aggregate(z, lam, aggregation_mask(0.0, 16))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z))


def test_phi_one_rows_identical_across_clients():
    """phi=1: every client sees the same aggregated tensor (broadcastable)."""
    key = jax.random.PRNGKey(2)
    z = _rand(key, (6, 8, 30), jnp.float32)
    lam = _lam(jax.random.PRNGKey(3), 6)
    out = np.asarray(phi_aggregate(z, lam, aggregation_mask(1.0, 8)))
    for i in range(1, 6):
        np.testing.assert_allclose(out[i], out[0], atol=1e-6)
    # and the value is the lambda-weighted sum
    expect = np.einsum("c,cbq->bq", np.asarray(lam), np.asarray(z))
    np.testing.assert_allclose(out[0], expect, atol=1e-5)


def test_one_hot_lambda_selects_client():
    """lam = e_k makes the aggregate equal client k's rows."""
    key = jax.random.PRNGKey(4)
    z = _rand(key, (4, 6, 12), jnp.float32)
    lam = jnp.array([0.0, 0.0, 1.0, 0.0])
    out = np.asarray(phi_aggregate(z, lam, aggregation_mask(1.0, 6)))
    np.testing.assert_allclose(out[0], np.asarray(z)[2], atol=1e-6)


@given(
    c=st.integers(1, 5),
    b=st.integers(1, 12),
    phi=st.sampled_from([0.0, 0.3, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mask_boundary_matches_ceil(c, b, phi, seed):
    """Exactly ceil(phi*b) slots are aggregated — the paper's count."""
    m = math.ceil(phi * b)
    mask = np.asarray(aggregation_mask(phi, b))
    assert int(mask.sum()) == m
    key = jax.random.PRNGKey(seed)
    z = _rand(key, (c, b, 9), jnp.float32)
    lam = _lam(jax.random.PRNGKey(seed % 1000 + 1), c)
    out = np.asarray(phi_aggregate(z, lam, jnp.asarray(mask)))
    zn = np.asarray(z)
    # unmasked slots untouched
    np.testing.assert_array_equal(out[:, m:], zn[:, m:])
    # masked slots identical across clients
    for i in range(1, c):
        np.testing.assert_allclose(out[i, :m], out[0, :m], atol=1e-6)


def test_phi_aggregate_nd_matches_flat():
    key = jax.random.PRNGKey(5)
    z = _rand(key, (3, 4, 2, 5, 7), jnp.float32)
    lam = _lam(jax.random.PRNGKey(6), 3)
    mask = aggregation_mask(0.5, 4)
    out = phi_aggregate_nd(z, lam, mask)
    ref = phi_aggregate_ref(z, lam, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# sgd_update vs ref
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 9000),
    lr=st.floats(1e-5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_update_matches_ref(n, lr, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    w = _rand(k1, (n,), jnp.float32)
    g = _rand(k2, (n,), jnp.float32)
    out = sgd_update(w, g, jnp.float32(lr))
    ref = sgd_update_ref(w, g, lr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6,
                               rtol=1e-6)


@pytest.mark.parametrize("shape", [(3, 3, 1, 8), (8,), (32, 10), (2, 2, 2, 2)])
def test_sgd_update_preserves_shape(shape):
    key = jax.random.PRNGKey(11)
    w = _rand(key, shape, jnp.float32)
    g = jnp.ones(shape, jnp.float32)
    out = sgd_update(w, g, jnp.float32(0.1))
    assert out.shape == shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(w) - 0.1,
                               atol=1e-6)


def test_sgd_zero_lr_is_identity():
    key = jax.random.PRNGKey(12)
    w = _rand(key, (100,), jnp.float32)
    g = _rand(jax.random.PRNGKey(13), (100,), jnp.float32)
    out = sgd_update(w, g, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(w))


def test_kernel_jits_and_lowers():
    """The kernel must survive jit.lower (the AOT path requirement)."""
    spec = jax.ShapeDtypeStruct((3, 8, 64), jnp.float32)
    lspec = jax.ShapeDtypeStruct((3,), jnp.float32)
    mspec = jax.ShapeDtypeStruct((8,), jnp.float32)
    lowered = jax.jit(phi_aggregate).lower(spec, lspec, mspec)
    assert lowered is not None
