"""Cross-layer integration: the exported artifact semantics end-to-end in
python (mirrors what the rust coordinator does each round)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import aggregation_mask

CFG = model.MNIST_LIKE


def _setup(c=2, cut=2, seed=0):
    params = model.init_params(CFG, jnp.array([0, seed], jnp.uint32))
    pc, ps = model.split_params(params, cut)
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (c, CFG.batch, CFG.img, CFG.img, CFG.channels))
    y = jax.random.randint(ky, (c, CFG.batch), 0, CFG.num_classes)
    return params, pc, ps, x, y


@pytest.mark.parametrize("phi", [0.0, 0.5, 1.0])
def test_full_round_decreases_loss(phi):
    """A few complete EPSL rounds must reduce the global loss."""
    c, cut = 2, 2
    _, pc, ps, x, y = _setup(c, cut)
    lam = jnp.array([0.5, 0.5])
    mask = aggregation_mask(phi, CFG.batch)
    lr = jnp.float32(0.1)
    pcs = [list(pc) for _ in range(c)]
    first = None
    last = None
    for _ in range(6):
        sm = jnp.stack(
            [model.client_fwd(CFG, cut, pcs[i], x[i]) for i in range(c)])
        ps, cagg, cunagg, loss, _ = model.server_train(
            CFG, cut, c, ps, sm, y, lam, mask, lr)
        if first is None:
            first = float(loss)
        last = float(loss)
        for i in range(c):
            g = mask[:, None, None, None] * cagg \
                + (1.0 - mask)[:, None, None, None] * cunagg[i]
            pcs[i] = model.client_step(CFG, cut, pcs[i], x[i], g, lr)
    assert last < first, (first, last)


def test_broadcast_gradient_identical_for_all_clients():
    """The aggregated cut-layer gradient must be client-independent — the
    physical precondition of the paper's downlink *broadcast* (stage 5)."""
    c, cut = 3, 2
    _, pc, ps, x, y = _setup(c, cut, seed=3)
    lam = jnp.array([0.3, 0.3, 0.4])
    mask = aggregation_mask(1.0, CFG.batch)
    sm = jnp.stack([model.client_fwd(CFG, cut, pc, x[i]) for i in range(c)])
    _, cagg, _, _, _ = model.server_train(
        CFG, cut, c, ps, sm, y, lam, mask, jnp.float32(0.1))
    # cut_agg is a single (b, ...) tensor — identical for every client by
    # construction. Verify it is finite and non-trivial.
    a = np.asarray(cagg)
    assert np.all(np.isfinite(a))
    assert np.abs(a).max() > 0


@pytest.mark.parametrize("cut", [1, 2, 3, 4])
def test_server_bp_workload_shrinks_with_phi(cut):
    """eq. 17's compute claim, checked *numerically*: with phi=1 the
    unaggregated cotangent is zero, so the unagg weight-gradient term
    vanishes and the server update equals the virtual-batch update alone."""
    c = 2
    _, pc, ps, x, y = _setup(c, cut, seed=5)
    lam = jnp.array([0.5, 0.5])
    sm = jnp.stack([model.client_fwd(CFG, cut, pc, x[i]) for i in range(c)])
    new1, _, cunagg1, _, _ = model.server_train(
        CFG, cut, c, ps, sm, y, lam, aggregation_mask(1.0, CFG.batch),
        jnp.float32(0.1))
    # phi=1: all unicast gradients zero
    assert float(jnp.max(jnp.abs(cunagg1))) == 0.0
    # and params still moved (aggregated BP ran)
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(new1, ps))
    assert moved


def test_mask_count_exact_ceil():
    for phi in [0.0, 0.01, 0.3, 0.5, 0.99, 1.0]:
        m = aggregation_mask(phi, CFG.batch)
        assert int(np.asarray(m).sum()) == math.ceil(phi * CFG.batch)


def test_eval_improves_with_training():
    """Eval artifact semantics: accuracy on the train batch improves."""
    c, cut = 2, 2
    params, pc, ps, x, y = _setup(c, cut, seed=8)
    lam = jnp.array([0.5, 0.5])
    mask = aggregation_mask(0.5, CFG.batch)
    lr = jnp.float32(0.15)
    pcs = [list(pc) for _ in range(c)]
    xe = x[0][: CFG.batch]
    ye = y[0][: CFG.batch]

    def acc(pc_eval, ps_eval):
        logits = model.server_fwd(
            CFG, cut, ps_eval, model.client_fwd(CFG, cut, pc_eval, xe))
        return float(jnp.mean((jnp.argmax(logits, -1) == ye)))

    a0 = acc(pcs[0], ps)
    for _ in range(15):
        sm = jnp.stack(
            [model.client_fwd(CFG, cut, pcs[i], x[i]) for i in range(c)])
        ps, cagg, cunagg, _, _ = model.server_train(
            CFG, cut, c, ps, sm, y, lam, mask, lr)
        for i in range(c):
            g = mask[:, None, None, None] * cagg \
                + (1.0 - mask)[:, None, None, None] * cunagg[i]
            pcs[i] = model.client_step(CFG, cut, pcs[i], x[i], g, lr)
    a1 = acc(pcs[0], ps)
    assert a1 > a0, (a0, a1)
