"""AOT export: manifest consistency + HLO text well-formedness."""

import json
import os
import subprocess
import sys

import pytest

ART = "/tmp/epsl_test_artifacts"


@pytest.fixture(scope="module")
def fast_artifacts():
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", ART, "--fast"],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_structure(fast_artifacts):
    m = fast_artifacts
    assert m["version"] == 1
    assert "mnist" in m["families"]
    fam = m["families"]["mnist"]
    for key in ("init", "eval", "client_fwd", "client_step", "server_train",
                "phi_agg"):
        assert key in fam["artifacts"], key


def test_all_files_exist_and_parse(fast_artifacts):
    fam = fast_artifacts["families"]["mnist"]

    def walk(entry):
        if isinstance(entry, dict) and "file" in entry:
            yield entry
        elif isinstance(entry, dict):
            for v in entry.values():
                yield from walk(v)

    n = 0
    for entry in walk(fam["artifacts"]):
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path), path
        text = open(path).read()
        assert "ENTRY" in text, f"no ENTRY computation in {path}"
        assert "HloModule" in text
        n += 1
    assert n == 6


def test_io_spec_shapes(fast_artifacts):
    fam = fast_artifacts["families"]["mnist"]
    st = fam["artifacts"]["server_train"]["2"]["2"]
    names = [s["name"] for s in st["inputs"]]
    # server params then (smashed, y, lam, mask, lr)
    assert names[-5:] == ["smashed", "y", "lam", "mask", "lr"]
    smashed = st["inputs"][-5]
    assert smashed["shape"] == [2, fam["batch"]] + fam["smashed_shape"]["2"]
    outs = [s["name"] for s in st["outputs"]]
    assert outs[-4:] == ["cut_agg", "cut_unagg", "loss", "ncorrect"]
    n_server_params = len(fam["params"]) - fam["client_param_count"]["2"]
    assert len(st["outputs"]) == n_server_params + 4


def test_param_split_counts(fast_artifacts):
    fam = fast_artifacts["families"]["mnist"]
    assert fam["client_param_count"]["2"] == 6
    assert len(fam["params"]) == 20
