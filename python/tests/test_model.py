"""L2 correctness: split-model semantics, EPSL vs PSL, gradient checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import aggregation_mask

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")

CFG = model.MNIST_LIKE


def _params(seed=0):
    return model.init_params(CFG, jnp.array([0, seed], jnp.uint32))


def _batch(key, c=2):
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (c, CFG.batch, CFG.img, CFG.img, CFG.channels))
    y = jax.random.randint(ky, (c, CFG.batch), 0, CFG.num_classes)
    return x, y


# ---------------------------------------------------------------------------
# Structure
# ---------------------------------------------------------------------------


def test_param_specs_shapes():
    params = _params()
    specs = model.param_specs(CFG)
    assert len(params) == len(specs) == 20
    for p, (name, shape) in zip(params, specs):
        assert p.shape == shape, name


@pytest.mark.parametrize("cut", model.CUTS)
def test_split_is_prefix_suffix(cut):
    params = _params()
    pc, ps = model.split_params(params, cut)
    assert len(pc) == model.client_param_count(cut)
    assert len(pc) + len(ps) == len(params)


@pytest.mark.parametrize("cut", model.CUTS)
def test_client_server_compose_to_full(cut):
    """client_fwd then server_fwd must equal full_fwd for every cut."""
    params = _params()
    pc, ps = model.split_params(params, cut)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (CFG.batch, CFG.img, CFG.img, CFG.channels))
    s = model.client_fwd(CFG, cut, pc, x)
    assert s.shape == (CFG.batch,) + CFG.smashed_shape(cut)
    logits_split = model.server_fwd(CFG, cut, ps, s)
    logits_full = model.full_fwd(CFG, params, x)
    np.testing.assert_allclose(np.asarray(logits_split),
                               np.asarray(logits_full), atol=1e-5)


def test_smashed_shapes_match_config():
    assert CFG.smashed_shape(1) == (16, 16, 8)
    assert CFG.smashed_shape(2) == (16, 16, 8)
    assert CFG.smashed_shape(3) == (8, 8, 16)
    assert CFG.smashed_shape(4) == (4, 4, 32)


def test_init_deterministic_and_seed_sensitive():
    a = _params(1)
    b = _params(1)
    c = _params(2)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert any(
        not np.allclose(np.asarray(pa), np.asarray(pc))
        for pa, pc in zip(a, c))


# ---------------------------------------------------------------------------
# EPSL semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cut", [1, 3])
def test_epsl_phi0_equals_psl(cut):
    """phi=0 must reproduce plain PSL (paper: 'PSL is a special case')."""
    params = _params()
    _, ps = model.split_params(params, cut)
    key = jax.random.PRNGKey(5)
    c = 3
    x, y = _batch(key, c)
    pc, _ = model.split_params(params, cut)
    sm = jnp.stack([model.client_fwd(CFG, cut, pc, x[i]) for i in range(c)])
    lam = jnp.array([0.2, 0.3, 0.5])
    mask0 = aggregation_mask(0.0, CFG.batch)
    new_p, _cagg, cunagg, loss, ncorr = model.server_train(
        CFG, cut, c, ps, sm, y, lam, mask0, jnp.float32(0.05))
    ref_p, ref_g, ref_loss, ref_n = model.psl_server_train_ref(
        CFG, cut, c, ps, sm, y, lam, 0.05)
    assert abs(float(loss) - float(ref_loss)) < 1e-6
    assert float(ncorr) == float(ref_n)
    for a, b in zip(new_p, ref_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    np.testing.assert_allclose(np.asarray(cunagg), np.asarray(ref_g),
                               atol=1e-5)


def test_epsl_phi1_cut_grads_broadcastable():
    """phi=1: unagg grads vanish; agg grad is one tensor for all clients."""
    cut, c = 2, 4
    params = _params()
    pc, ps = model.split_params(params, cut)
    key = jax.random.PRNGKey(6)
    x, y = _batch(key, c)
    sm = jnp.stack([model.client_fwd(CFG, cut, pc, x[i]) for i in range(c)])
    lam = jnp.full((c,), 1.0 / c)
    new_p, cagg, cunagg, loss, _ = model.server_train(
        CFG, cut, c, ps, sm, y, lam, aggregation_mask(1.0, CFG.batch),
        jnp.float32(0.05))
    np.testing.assert_array_equal(np.asarray(cunagg),
                                  np.zeros_like(np.asarray(cunagg)))
    assert np.all(np.isfinite(np.asarray(cagg)))
    assert np.isfinite(float(loss))


def test_aggregate_then_bp_equals_bp_then_aggregate_on_linear_tail():
    """The paper's linearity argument (§IV): for a linear server-side model,
    aggregating last-layer gradients then back-propagating equals
    back-propagating then aggregating."""
    c, b, q, nc = 3, 8, 20, 5
    key = jax.random.PRNGKey(8)
    kw, kz, ks = jax.random.split(key, 3)
    w = jax.random.normal(kw, (q, nc))
    s = jax.random.normal(ks, (c, b, q))
    y = jax.random.randint(kz, (c, b), 0, nc)
    lam = jnp.array([0.5, 0.25, 0.25])

    def fwd(s_flat):
        return s_flat @ w

    logits = fwd(s.reshape(c * b, q))
    onehot = jax.nn.one_hot(y.reshape(c * b), nc)
    z = (jax.nn.softmax(logits) - onehot).reshape(c, b, nc)

    # BP-then-aggregate: per-client cut grads, lambda-aggregated.
    cut_per_client = jnp.einsum("cbn,qn->cbq", z, w)
    bp_then_agg = jnp.einsum("c,cbq->bq", lam, cut_per_client)
    # Aggregate-then-BP (EPSL): aggregate z, then one BP pass.
    zbar = jnp.einsum("c,cbn->bn", lam, z)
    agg_then_bp = jnp.einsum("bn,qn->bq", zbar, w)
    np.testing.assert_allclose(np.asarray(bp_then_agg),
                               np.asarray(agg_then_bp), atol=1e-5)


@given(phi=st.sampled_from([0.0, 0.25, 0.5, 1.0]),
       seed=st.integers(0, 10_000))
def test_server_train_outputs_finite(phi, seed):
    cut, c = 2, 2
    params = _params(seed % 7)
    pc, ps = model.split_params(params, cut)
    key = jax.random.PRNGKey(seed)
    x, y = _batch(key, c)
    sm = jnp.stack([model.client_fwd(CFG, cut, pc, x[i]) for i in range(c)])
    lam = jnp.array([0.6, 0.4])
    new_p, cagg, cunagg, loss, ncorr = model.server_train(
        CFG, cut, c, ps, sm, y, lam, aggregation_mask(phi, CFG.batch),
        jnp.float32(0.05))
    assert np.isfinite(float(loss))
    assert 0.0 <= float(ncorr) <= c * CFG.batch
    for p in new_p:
        assert np.all(np.isfinite(np.asarray(p)))
    assert np.all(np.isfinite(np.asarray(cagg)))
    assert np.all(np.isfinite(np.asarray(cunagg)))


def test_client_step_moves_params_downhill():
    """A full EPSL round (client fwd -> server train -> client step) must
    reduce the global loss on the same batch for a small lr."""
    cut, c = 2, 2
    params = _params()
    pc, ps = model.split_params(params, cut)
    key = jax.random.PRNGKey(9)
    x, y = _batch(key, c)
    lam = jnp.array([0.5, 0.5])
    mask = aggregation_mask(0.5, CFG.batch)
    lr = jnp.float32(0.1)

    def global_loss(pc_list, ps_list):
        total = 0.0
        for i in range(c):
            s = model.client_fwd(CFG, cut, pc_list[i], x[i])
            logits = model.server_fwd(CFG, cut, ps_list, s)
            logp = jax.nn.log_softmax(logits)
            onehot = jax.nn.one_hot(y[i], CFG.num_classes)
            total = total + float(lam[i]) * float(
                jnp.mean(-jnp.sum(onehot * logp, axis=-1)))
        return total

    pcs = [list(pc) for _ in range(c)]
    loss_before = global_loss(pcs, ps)
    for _ in range(5):
        sm = jnp.stack(
            [model.client_fwd(CFG, cut, pcs[i], x[i]) for i in range(c)])
        ps, cagg, cunagg, _, _ = model.server_train(
            CFG, cut, c, ps, sm, y, lam, mask, lr)
        for i in range(c):
            g = mask[:, None, None, None] * cagg + \
                (1.0 - mask)[:, None, None, None] * cunagg[i]
            pcs[i] = model.client_step(CFG, cut, pcs[i], x[i], g, lr)
    loss_after = global_loss(pcs, ps)
    assert loss_after < loss_before, (loss_before, loss_after)


def test_full_eval_counts():
    params = _params()
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(
        key, (CFG.eval_batch, CFG.img, CFG.img, CFG.channels))
    y = jax.random.randint(key, (CFG.eval_batch,), 0, CFG.num_classes)
    loss, ncorr = model.full_eval(CFG, params, x, y)
    assert np.isfinite(float(loss))
    assert 0 <= float(ncorr) <= CFG.eval_batch


def test_ham_family_shapes():
    cfg = model.HAM_LIKE
    params = model.init_params(cfg, jnp.array([0, 0], jnp.uint32))
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (cfg.batch, cfg.img, cfg.img, cfg.channels))
    logits = model.full_fwd(cfg, params, x)
    assert logits.shape == (cfg.batch, cfg.num_classes)
