"""AOT compile path: lower every training graph to HLO *text* + manifest.

This is the only place python touches the system. ``make artifacts`` runs it
once; the rust coordinator then loads ``artifacts/*.hlo.txt`` through the
PJRT C API and never calls back into python.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links against) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every graph is lowered with ``return_tuple=True`` so the rust side always
unwraps one tuple literal regardless of arity.

Output layout:
    artifacts/<name>.hlo.txt      one per exported graph
    artifacts/manifest.json       full shape/dtype/param-split metadata
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.phi_aggregate import phi_aggregate

# Client-count variants exported for server_train (the rust coordinator picks
# the artifact matching the experiment's C; Fig. 9 sweeps these).
CLIENT_COUNTS = (1, 2, 5, 10, 15, 20)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_str(dt) -> str:
    dt = jnp.dtype(dt)
    return {"float32": "f32", "int32": "i32", "uint32": "u32",
            "bfloat16": "bf16"}[dt.name]


def _spec(name: str, shape: Sequence[int], dtype) -> dict:
    return {"name": name, "dtype": _dtype_str(dtype),
            "shape": [int(d) for d in shape]}


class Exporter:
    """Lowers graphs, writes HLO files, accumulates manifest entries."""

    def __init__(self, out_dir: str, verbose: bool = True):
        self.out_dir = out_dir
        self.verbose = verbose
        self.n_files = 0

    def export(self, fname: str, fn, arg_specs: List[Tuple[str, tuple, object]],
               out_specs: List[Tuple[str, tuple, object]]) -> dict:
        """Lower fn(*args) to HLO text; returns the manifest entry."""
        t0 = time.time()
        shaped = [jax.ShapeDtypeStruct(s, d) for (_n, s, d) in arg_specs]
        lowered = jax.jit(fn).lower(*shaped)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        self.n_files += 1
        if self.verbose:
            print(f"  [{self.n_files:3d}] {fname:48s} "
                  f"{len(text) // 1024:5d} KiB  {time.time() - t0:5.1f}s")
        return {
            "file": fname,
            "inputs": [_spec(n, s, d) for (n, s, d) in arg_specs],
            "outputs": [_spec(n, s, d) for (n, s, d) in out_specs],
        }


def export_family(ex: Exporter, cfg: model.ModelConfig,
                  client_counts: Sequence[int], cuts: Sequence[int]) -> dict:
    """Export every graph for one model family; returns manifest subtree."""
    b = cfg.batch
    specs = model.param_specs(cfg)
    pspecs = [(n, s, jnp.float32) for (n, s) in specs]
    img_shape = (b, cfg.img, cfg.img, cfg.channels)

    fam: dict = {
        "channels": cfg.channels,
        "num_classes": cfg.num_classes,
        "img": cfg.img,
        "width": cfg.width,
        "batch": b,
        "eval_batch": cfg.eval_batch,
        "params": [{"name": n, "shape": list(s)} for (n, s) in specs],
        "client_param_count": {
            str(k): model.client_param_count(k) for k in cuts
        },
        "smashed_shape": {
            str(k): list(cfg.smashed_shape(k)) for k in cuts
        },
        "artifacts": {},
    }
    arts = fam["artifacts"]

    # ---- init ----
    arts["init"] = ex.export(
        f"{cfg.name}_init.hlo.txt",
        lambda seed: tuple(model.init_params(cfg, seed)),
        [("seed", (2,), jnp.uint32)],
        [(n, s, jnp.float32) for (n, s) in specs],
    )

    # ---- eval (full model, fixed eval batch) ----
    eb = cfg.eval_batch
    arts["eval"] = ex.export(
        f"{cfg.name}_eval.hlo.txt",
        lambda *a: model.full_eval(cfg, list(a[:len(specs)]), a[len(specs)],
                                   a[len(specs) + 1]),
        pspecs + [("x", (eb, cfg.img, cfg.img, cfg.channels), jnp.float32),
                  ("y", (eb,), jnp.int32)],
        [("loss", (), jnp.float32), ("ncorrect", (), jnp.float32)],
    )

    arts["client_fwd"] = {}
    arts["client_step"] = {}
    arts["server_train"] = {}
    arts["phi_agg"] = {}

    for cut in cuts:
        ncp = model.client_param_count(cut)
        csp = pspecs[:ncp]
        ssp = pspecs[ncp:]
        smash = cfg.smashed_shape(cut)

        # ---- client_fwd ----
        def cf(*a, _cut=cut, _ncp=ncp):
            return (model.client_fwd(cfg, _cut, list(a[:_ncp]), a[_ncp]),)

        arts["client_fwd"][str(cut)] = ex.export(
            f"{cfg.name}_client_fwd_cut{cut}.hlo.txt", cf,
            csp + [("x", img_shape, jnp.float32)],
            [("smashed", (b,) + smash, jnp.float32)],
        )

        # ---- client_step ----
        def cs(*a, _cut=cut, _ncp=ncp):
            return tuple(
                model.client_step(cfg, _cut, list(a[:_ncp]), a[_ncp],
                                  a[_ncp + 1], a[_ncp + 2]))

        arts["client_step"][str(cut)] = ex.export(
            f"{cfg.name}_client_step_cut{cut}.hlo.txt", cs,
            csp + [("x", img_shape, jnp.float32),
                   ("g_cut", (b,) + smash, jnp.float32),
                   ("lr", (), jnp.float32)],
            [(n, s, jnp.float32) for (n, s, _d) in csp],
        )

        # ---- server_train per client count ----
        arts["server_train"][str(cut)] = {}
        for cc in client_counts:
            def st(*a, _cut=cut, _cc=cc, _nsp=len(ssp)):
                new_p, cut_agg, cut_unagg, loss, ncorr = model.server_train(
                    cfg, _cut, _cc, list(a[:_nsp]), a[_nsp], a[_nsp + 1],
                    a[_nsp + 2], a[_nsp + 3], a[_nsp + 4])
                return tuple(new_p) + (cut_agg, cut_unagg, loss, ncorr)

            arts["server_train"][str(cut)][str(cc)] = ex.export(
                f"{cfg.name}_server_train_cut{cut}_c{cc}.hlo.txt", st,
                ssp + [("smashed", (cc, b) + smash, jnp.float32),
                       ("y", (cc, b), jnp.int32),
                       ("lam", (cc,), jnp.float32),
                       ("mask", (b,), jnp.float32),
                       ("lr", (), jnp.float32)],
                [(n, s, jnp.float32) for (n, s, _d) in ssp] +
                [("cut_agg", (b,) + smash, jnp.float32),
                 ("cut_unagg", (cc, b) + smash, jnp.float32),
                 ("loss", (), jnp.float32),
                 ("ncorrect", (), jnp.float32)],
            )

        # ---- standalone phi_aggregate kernel (L1 perf bench target) ----
        q = smash[0] * smash[1] * smash[2]
        cc0 = 5 if 5 in client_counts else client_counts[0]

        def pa(z, lam, mask, _q=q):
            return (phi_aggregate(z, lam, mask),)

        arts["phi_agg"][str(cut)] = ex.export(
            f"{cfg.name}_phi_agg_cut{cut}.hlo.txt", pa,
            [("z", (cc0, b, q), jnp.float32), ("lam", (cc0,), jnp.float32),
             ("mask", (b,), jnp.float32)],
            [("out", (cc0, b, q), jnp.float32)],
        )

    return fam


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--families", default="mnist,ham")
    ap.add_argument("--cuts", default="1,2,3,4")
    ap.add_argument("--clients", default=",".join(map(str, CLIENT_COUNTS)))
    ap.add_argument("--fast", action="store_true",
                    help="minimal artifact set (CI smoke): mnist, cut 2, C=2")
    args = ap.parse_args()

    if args.fast:
        families, cuts, clients = ["mnist"], [2], [2]
    else:
        families = args.families.split(",")
        cuts = [int(c) for c in args.cuts.split(",")]
        clients = [int(c) for c in args.clients.split(",")]

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    ex = Exporter(args.out)
    manifest = {
        "version": 1,
        "client_counts": clients,
        "cuts": cuts,
        "families": {},
    }
    for fname in families:
        cfg = model.FAMILIES[fname]
        print(f"family {fname}: b={cfg.batch} img={cfg.img} "
              f"classes={cfg.num_classes}")
        manifest["families"][fname] = export_family(ex, cfg, clients, cuts)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {ex.n_files} artifacts + manifest.json "
          f"in {time.time() - t0:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
