"""L1 Pallas kernel: EPSL last-layer gradient aggregation (paper eq. 5-6).

The EPSL hot-spot: given per-client, per-sample tensors ``z[C, b, q]``
(last-layer activations' gradients, or smashed activations when building the
virtual aggregated batch), dataset weights ``lam[C]`` (lambda_i = D_i / D) and
an aggregation mask ``mask[b]`` (1.0 for the first ceil(phi*b) sample slots,
0.0 otherwise), produce

    out[i, j, :] = mask[j] * sum_k lam[k] * z[k, j, :]
                 + (1 - mask[j]) * z[i, j, :]

i.e. masked sample slots are replaced by the client-wise lambda-weighted
aggregate (identical across clients -> broadcastable downlink), unmasked
slots pass through untouched (unicast downlink). phi = 0 makes this the
identity (EPSL degenerates to PSL, as in the paper).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks feature
tiles; each program holds a ``(C, b, qt)`` block in VMEM and performs the
C-reduction locally — the VMEM-resident reduction replaces the
threadblock-per-row shared-memory reduction a CUDA port would use. The
feature tile ``qt`` is sized so the block fits comfortably in ~16 MiB VMEM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO ops and runs on any backend.
Correctness is pinned against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature-tile size. 512 f32 lanes x (C*b) rows stays well under VMEM for the
# C/b ranges this system uses (C <= 32, b <= 64: 32*64*512*4 B = 4 MiB).
DEFAULT_TILE_Q = 512


def _phi_aggregate_kernel(lam_ref, mask_ref, z_ref, out_ref):
    """One grid step: one feature tile, all clients and samples resident."""
    z = z_ref[...]  # (C, b, qt)
    lam = lam_ref[...]  # (C,)
    mask = mask_ref[...]  # (b,)
    # Client-wise lambda-weighted aggregate: (b, qt).
    agg = jnp.einsum("c,cbq->bq", lam, z, preferred_element_type=jnp.float32)
    agg = agg.astype(z.dtype)
    m = mask[None, :, None].astype(z.dtype)
    out_ref[...] = m * agg[None, :, :] + (1.0 - m) * z


def phi_aggregate(z: jax.Array, lam: jax.Array, mask: jax.Array,
                  tile_q: int = DEFAULT_TILE_Q) -> jax.Array:
    """Masked client-wise aggregation of last-layer gradients (Pallas).

    Args:
      z:    (C, b, q) per-client per-sample tensors.
      lam:  (C,) client dataset weights, sums to 1.
      mask: (b,) 1.0 where the sample slot participates in aggregation.
      tile_q: feature-tile width for the grid.

    Returns:
      (C, b, q) tensor; masked slots hold the aggregate (equal across the
      client axis), unmasked slots are untouched.
    """
    c, b, q = z.shape
    assert lam.shape == (c,), (lam.shape, c)
    assert mask.shape == (b,), (mask.shape, b)
    qt = min(tile_q, q)
    grid = (pl.cdiv(q, qt),)
    return pl.pallas_call(
        _phi_aggregate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((c, b, qt), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((c, b, qt), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((c, b, q), z.dtype),
        interpret=True,
    )(lam, mask, z)


def phi_aggregate_nd(z: jax.Array, lam: jax.Array, mask: jax.Array,
                     tile_q: int = DEFAULT_TILE_Q) -> jax.Array:
    """phi_aggregate for (C, b, *feature_dims): flattens trailing dims."""
    c, b = z.shape[:2]
    feat = z.shape[2:]
    q = 1
    for d in feat:
        q *= int(d)
    out = phi_aggregate(z.reshape(c, b, q), lam, mask, tile_q=tile_q)
    return out.reshape((c, b) + feat)


def _sgd_kernel(lr_ref, w_ref, g_ref, out_ref):
    out_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(w: jax.Array, g: jax.Array, lr: jax.Array,
               tile: int = 4096) -> jax.Array:
    """Fused SGD step ``w - lr * g`` as a 1-D tiled Pallas kernel.

    Applied per-tensor over the flattened parameter; lr is a scalar array.
    """
    shape = w.shape
    n = w.size
    wf = w.reshape(n)
    gf = g.reshape(n)
    t = min(tile, n)
    grid = (pl.cdiv(n, t),)
    lr_arr = jnp.reshape(lr.astype(w.dtype), (1,))
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), w.dtype),
        interpret=True,
    )(lr_arr, wf, gf)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("tile_q",))
def phi_aggregate_jit(z, lam, mask, tile_q=DEFAULT_TILE_Q):
    return phi_aggregate(z, lam, mask, tile_q=tile_q)
