"""Pure-jnp oracles for the Pallas kernels (the correctness pin).

These implement paper eq. (5)-(6) semantics directly with jnp ops and are
what the pytest/hypothesis suites compare the Pallas kernels against.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def phi_aggregate_ref(z, lam, mask):
    """Reference for kernels.phi_aggregate.

    out[i, j] = mask[j] * sum_k lam[k] z[k, j] + (1 - mask[j]) * z[i, j].
    """
    c, b = z.shape[0], z.shape[1]
    assert lam.shape == (c,)
    assert mask.shape == (b,)
    extra = (1,) * (z.ndim - 2)
    lam_b = lam.reshape((c, 1) + extra).astype(jnp.float32)
    agg = jnp.sum(lam_b * z.astype(jnp.float32), axis=0, keepdims=True)
    agg = agg.astype(z.dtype)
    m = mask.reshape((1, b) + extra).astype(z.dtype)
    return m * agg + (1.0 - m) * z


def sgd_update_ref(w, g, lr):
    """Reference for kernels.sgd_update."""
    return w - jnp.asarray(lr, w.dtype) * g


def aggregation_mask(phi: float, b: int):
    """mask[j] = 1 for j < ceil(phi*b) — the paper's aggregated slot count."""
    m = math.ceil(phi * b)
    return jnp.where(jnp.arange(b) < m, 1.0, 0.0).astype(jnp.float32)
