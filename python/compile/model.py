"""L2: the split model + EPSL train-step graphs (build-time JAX).

Implements the paper's training procedure (§IV, Algorithm 1) as a family of
jit-lowerable functions over a small residual CNN ("SplitNet") that mirrors
ResNet-18's block topology at reproduction scale (see DESIGN.md §3 for the
substitution note — latency experiments use the paper's exact ResNet-18
Table-IV profile analytically; *training* experiments run this network
end-to-end through PJRT from the rust coordinator).

The network is staged so that every stage boundary is a legal cut layer
(paper Fig. 6's "potential choice of the cut layer"):

    stage 1: conv3x3(w1) + relu
    stage 2: residual block (w -> w)
    stage 3: residual block (w -> 2w, stride 2)
    stage 4: residual block (2w -> 4w, stride 2)
    head:    global-avg-pool + fc          (always server-side)

Cut after stage k in {1,2,3,4}: client owns stages 1..k, server owns the
rest. Parameters live in one canonical ordered list; per-cut client/server
subsets are contiguous prefix/suffix (recorded in the manifest).

Exported graphs (lowered to HLO text by aot.py, executed from rust):
  init                      seed[2]u32                    -> all params
  client_fwd_cut{k}         (P_c..., X[b,...])            -> smashed S
  server_train_cut{k}_c{C}  (P_s..., S[C,b,...], y[C,b],
                             lam[C], mask[b], lr)         -> (P_s'...,
                             cut_agg[b,...], cut_unagg[C,b,...],
                             loss, ncorrect)
  client_step_cut{k}        (P_c..., X, g_cut[b,...], lr) -> P_c'...
  eval                      (P..., X[B,...], y[B])        -> (loss, ncorrect)

EPSL semantics implemented exactly as eq. (5)-(6): the last-layer
activations' gradients of the first ceil(phi*b) sample slots of every client
are lambda-aggregated client-wise *before* the remaining server BP. The
aggregated slots back-propagate through a "virtual batch" whose inputs are
the lambda-aggregated smashed activations (one BP pass over ceil(phi*b)
virtual samples — matching the paper's server BP workload model, eq. 17) and
the resulting cut-layer gradient is identical for all clients, which is what
makes the downlink a broadcast (stage 5) rather than C unicasts. phi is
dynamic at runtime via the mask vector; phi=0 reproduces PSL bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.phi_aggregate import phi_aggregate_nd, sgd_update

# ----------------------------------------------------------------------------
# Configuration
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration for one model family."""

    name: str
    channels: int  # input image channels
    num_classes: int
    img: int = 16  # square input resolution
    width: int = 8  # base conv width (stages: w, w, 2w, 4w)
    batch: int = 32  # per-client mini-batch b
    eval_batch: int = 256

    @property
    def stage_widths(self) -> Tuple[int, int, int, int]:
        w = self.width
        return (w, w, 2 * w, 4 * w)

    def smashed_shape(self, cut: int) -> Tuple[int, int, int]:
        """(h, w, c) of the activations at cut layer `cut` (after stage cut)."""
        assert 1 <= cut <= 4
        ws = self.stage_widths
        if cut <= 2:
            return (self.img, self.img, ws[cut - 1])
        if cut == 3:
            return (self.img // 2, self.img // 2, ws[2])
        return (self.img // 4, self.img // 4, ws[3])


MNIST_LIKE = ModelConfig(name="mnist", channels=1, num_classes=10)
HAM_LIKE = ModelConfig(name="ham", channels=3, num_classes=7)

FAMILIES: Dict[str, ModelConfig] = {c.name: c for c in (MNIST_LIKE, HAM_LIKE)}
CUTS = (1, 2, 3, 4)

# ----------------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical ordered (name, shape) list for the full model."""
    w1, w2, w3, w4 = cfg.stage_widths
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    # stage 1
    specs.append(("s1.w", (3, 3, cfg.channels, w1)))
    specs.append(("s1.b", (w1,)))
    # stage 2: residual w1 -> w2 (stride 1, identity skip: w1 == w2)
    specs.append(("s2.wa", (3, 3, w1, w2)))
    specs.append(("s2.ba", (w2,)))
    specs.append(("s2.wb", (3, 3, w2, w2)))
    specs.append(("s2.bb", (w2,)))
    # stage 3: residual w2 -> w3, stride 2, projection skip
    specs.append(("s3.wa", (3, 3, w2, w3)))
    specs.append(("s3.ba", (w3,)))
    specs.append(("s3.wb", (3, 3, w3, w3)))
    specs.append(("s3.bb", (w3,)))
    specs.append(("s3.wp", (1, 1, w2, w3)))
    specs.append(("s3.bp", (w3,)))
    # stage 4: residual w3 -> w4, stride 2, projection skip
    specs.append(("s4.wa", (3, 3, w3, w4)))
    specs.append(("s4.ba", (w4,)))
    specs.append(("s4.wb", (3, 3, w4, w4)))
    specs.append(("s4.bb", (w4,)))
    specs.append(("s4.wp", (1, 1, w3, w4)))
    specs.append(("s4.bp", (w4,)))
    # head
    specs.append(("fc.w", (w4, cfg.num_classes)))
    specs.append(("fc.b", (cfg.num_classes,)))
    return specs


# Number of parameter tensors per stage (canonical-prefix bookkeeping).
_STAGE_PARAM_COUNTS = (2, 4, 6, 6)  # s1, s2, s3, s4


def client_param_count(cut: int) -> int:
    return sum(_STAGE_PARAM_COUNTS[:cut])


def split_params(params: Sequence[jax.Array], cut: int):
    n = client_param_count(cut)
    return list(params[:n]), list(params[n:])


def init_params(cfg: ModelConfig, seed: jax.Array) -> List[jax.Array]:
    """He-normal init; `seed` is a uint32[2] PRNG key payload."""
    key = jax.random.wrap_key_data(seed.astype(jnp.uint32))
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.split(".")[-1].startswith("b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = math.sqrt(2.0 / fan_in)
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


# ----------------------------------------------------------------------------
# Forward passes
# ----------------------------------------------------------------------------

_DN = ("NHWC", "HWIO", "NHWC")


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=_DN)
    return y + b[None, None, None, :]


def _stage1(p, x):
    return jax.nn.relu(_conv(x, p["s1.w"], p["s1.b"]))


def _resblock(p, prefix, x, stride, project):
    h = jax.nn.relu(_conv(x, p[f"{prefix}.wa"], p[f"{prefix}.ba"], stride))
    h = _conv(h, p[f"{prefix}.wb"], p[f"{prefix}.bb"])
    if project:
        skip = _conv(x, p[f"{prefix}.wp"], p[f"{prefix}.bp"], stride)
    else:
        skip = x
    return jax.nn.relu(h + skip)


def _head(p, x):
    pooled = jnp.mean(x, axis=(1, 2))  # global average pool
    return pooled @ p["fc.w"] + p["fc.b"]


_STAGES = (
    lambda p, x: _stage1(p, x),
    lambda p, x: _resblock(p, "s2", x, 1, False),
    lambda p, x: _resblock(p, "s3", x, 2, True),
    lambda p, x: _resblock(p, "s4", x, 2, True),
)


def forward_stages(params: Sequence[jax.Array], names: Sequence[str], x,
                   from_stage: int, to_stage: int, with_head: bool):
    """Run stages [from_stage, to_stage] (1-based, inclusive), then head."""
    p = dict(zip(names, params))
    h = x
    for s in range(from_stage, to_stage + 1):
        h = _STAGES[s - 1](p, h)
    if with_head:
        h = _head(p, h)
    return h


def full_names(cfg: ModelConfig) -> List[str]:
    return [n for n, _ in param_specs(cfg)]


def client_names(cfg: ModelConfig, cut: int) -> List[str]:
    return full_names(cfg)[:client_param_count(cut)]


def server_names(cfg: ModelConfig, cut: int) -> List[str]:
    return full_names(cfg)[client_param_count(cut):]


def client_fwd(cfg: ModelConfig, cut: int, params: Sequence[jax.Array], x):
    """Client-side FP: stages 1..cut. x: (b, img, img, ch) -> smashed."""
    return forward_stages(params, client_names(cfg, cut), x, 1, cut,
                          with_head=False)


def server_fwd(cfg: ModelConfig, cut: int, params: Sequence[jax.Array], s):
    """Server-side FP: stages cut+1..4 + head. s: (n, *smashed) -> logits."""
    return forward_stages(params, server_names(cfg, cut), s, cut + 1, 4,
                          with_head=True)


def full_fwd(cfg: ModelConfig, params: Sequence[jax.Array], x):
    return forward_stages(params, full_names(cfg), x, 1, 4, with_head=True)


# ----------------------------------------------------------------------------
# Loss / gradients
# ----------------------------------------------------------------------------


def _softmax_xent(logits, labels, num_classes):
    """Per-sample cross-entropy and its dL/dlogits (both unweighted)."""
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=logits.dtype)
    ce = -jnp.sum(onehot * logp, axis=-1)
    dlogits = jax.nn.softmax(logits) - onehot
    return ce, dlogits


def _ncorrect(logits, labels):
    return jnp.sum(
        (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


# ----------------------------------------------------------------------------
# Exported train-step graphs
# ----------------------------------------------------------------------------


def server_train(cfg: ModelConfig, cut: int, n_clients: int,
                 server_params: Sequence[jax.Array], smashed, labels, lam,
                 mask, lr):
    """EPSL server-side step (paper §IV stages 3-6, eq. 5-7).

    Args:
      server_params: server-side tensors (canonical suffix for this cut).
      smashed: (C, b, *smash) concatenated client smashed data (stage 2's
        uplink payload).
      labels:  (C, b) int32.
      lam:     (C,) dataset weights lambda_i = D_i / D.
      mask:    (b,) aggregation mask; mask[j] = 1 for j < ceil(phi*b).
      lr:      scalar learning rate eta_s.

    Returns:
      (new server params..., cut_agg (b,*smash) broadcast cut-layer gradient,
       cut_unagg (C,b,*smash) unicast cut-layer gradients (masked slots are
       zero), global weighted loss, ncorrect over C*b samples)
    """
    c, b = n_clients, cfg.batch
    smash = cfg.smashed_shape(cut)
    flat = smashed.reshape((c * b,) + smash)

    def fwd(p_list, s):
        return server_fwd(cfg, cut, p_list, s)

    # --- server FP over all C*b real samples (eq. 3, latency eq. 16) ---
    logits, pullback = jax.vjp(fwd, list(server_params), flat)
    labels_flat = labels.reshape(c * b)
    ce, dlogits = _softmax_xent(logits, labels_flat, cfg.num_classes)
    ncorr = _ncorrect(logits, labels_flat)
    # Global loss: sum_i lambda_i * (1/b) * sum_j CE_ij  (eq. 1 weighting).
    ce_cb = ce.reshape(c, b)
    loss = jnp.sum(lam[:, None] * ce_cb) / b

    z = dlogits.reshape(c, b, cfg.num_classes)

    # --- last-layer gradient aggregation (eq. 6) via the Pallas kernel ---
    z_mixed = phi_aggregate_nd(z, lam, mask)  # (C,b,nc); masked rows = zbar
    zbar = z_mixed[0]  # (b, nc): masked slots hold the aggregate

    # Virtual aggregated batch: lambda-aggregated smashed activations.
    s_mixed = phi_aggregate_nd(smashed, lam, mask)
    sbar = s_mixed[0]  # (b, *smash)

    # --- BP of the aggregated slots: one pass over ceil(phi*b) virtual
    # samples (eq. 5 first block; row weight 1/b) ---
    _, pullback_v = jax.vjp(fwd, list(server_params), sbar)
    cot_v = (mask[:, None] * zbar) / b
    gw_v, gs_v = pullback_v(cot_v)
    cut_agg = gs_v * b  # raw activations' gradients for the broadcast

    # --- BP of the unaggregated slots (eq. 5 remaining blocks; row weight
    # lambda_i / b) ---
    unmask = (1.0 - mask)[None, :, None]
    cot_r = (unmask * lam[:, None, None] * z / b).reshape(
        (c * b, cfg.num_classes))
    gw_r, gs_r = pullback(cot_r)
    # Recover raw (unweighted) activations' gradients for the unicast
    # downlink: divide the lambda_i/b row weight back out.
    lam_safe = jnp.maximum(lam, 1e-12)
    lam_b = lam_safe[:, None, None, None, None]
    cut_unagg = gs_r.reshape((c, b) + smash) * b / lam_b
    cut_unagg = cut_unagg * (1.0 - mask)[None, :, None, None, None]

    # --- parameter update (eq. 7) via the fused Pallas SGD kernel ---
    new_params = [
        sgd_update(w, gv + gr, lr)
        for w, gv, gr in zip(server_params, gw_v, gw_r)
    ]
    return new_params, cut_agg, cut_unagg, loss, ncorr


def client_step(cfg: ModelConfig, cut: int, client_params: Sequence[jax.Array],
                x, g_cut, lr):
    """Client-side BP + update (paper §IV stage 7, eq. 8-12).

    g_cut: (b, *smash) raw cut-layer activations' gradients for this client
    (rust assembles mask[j]*cut_agg[j] + (1-mask[j])*cut_unagg[i,j]).
    """
    b = cfg.batch

    def fwd(p_list, xx):
        return client_fwd(cfg, cut, p_list, xx)

    _, pullback = jax.vjp(fwd, list(client_params), x)
    gw, _gx = pullback(g_cut / b)  # eq. 9: every row weighted 1/b
    return [sgd_update(w, g, lr) for w, g in zip(client_params, gw)]


def full_eval(cfg: ModelConfig, params: Sequence[jax.Array], x, labels):
    """Full-model eval on a fixed-size batch: (mean CE, ncorrect)."""
    logits = full_fwd(cfg, params, x)
    ce, _ = _softmax_xent(logits, labels, cfg.num_classes)
    return jnp.mean(ce), _ncorrect(logits, labels)


# ----------------------------------------------------------------------------
# PSL reference step (pytest oracle: EPSL(phi=0) must match this; also used
# for the linear-tail equivalence test)
# ----------------------------------------------------------------------------


def psl_server_train_ref(cfg: ModelConfig, cut: int, n_clients: int,
                         server_params: Sequence[jax.Array], smashed, labels,
                         lam, lr):
    """Plain PSL: BP every sample with weight lambda_i/b, no aggregation."""
    c, b = n_clients, cfg.batch
    smash = cfg.smashed_shape(cut)
    flat = smashed.reshape((c * b,) + smash)
    logits, pullback = jax.vjp(
        lambda p, s: server_fwd(cfg, cut, p, s), list(server_params), flat)
    labels_flat = labels.reshape(c * b)
    ce, dlogits = _softmax_xent(logits, labels_flat, cfg.num_classes)
    ce_cb = ce.reshape(c, b)
    loss = jnp.sum(lam[:, None] * ce_cb) / b
    z = dlogits.reshape(c, b, cfg.num_classes)
    cot = (lam[:, None, None] * z / b).reshape((c * b, cfg.num_classes))
    gw, gs = pullback(cot)
    lam_safe = jnp.maximum(lam, 1e-12)
    cut_grads = gs.reshape((c, b) + smash) * b / lam_safe[:, None, None, None,
                                                          None]
    new_params = [w - lr * g for w, g in zip(server_params, gw)]
    return new_params, cut_grads, loss, _ncorrect(logits, labels_flat)
