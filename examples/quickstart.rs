//! Quickstart: load the AOT artifacts, run ONE full EPSL round across two
//! simulated clients, and print the per-stage latency breakdown — the
//! smallest end-to-end exercise of the public API.
//!
//! Usage: cargo run --release --example quickstart

use epsl::channel::{ChannelRealization, Deployment};
use epsl::config::Config;
use epsl::coordinator::{train, TrainerOptions};
use epsl::optim::{bcd, Problem};
use epsl::profile::resnet18;
use epsl::runtime::{select_backend, Backend, BackendChoice};
use epsl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Select a backend: the PJRT build-time artifacts when present,
    //    the pure-Rust native backend otherwise (python never runs at
    //    training time either way).
    let sel = select_backend("artifacts", BackendChoice::Auto)?;
    let (rt, manifest) = (sel.backend.as_ref(), &sel.manifest);
    println!("platform: {}", rt.platform());
    let fam = manifest.family("mnist")?;
    println!(
        "model: {} parameter tensors ({} floats), batch {}",
        fam.params.len(),
        fam.param_elements(),
        fam.batch
    );

    // 2. One EPSL round (2 clients, φ = 0.5) through the real runtime.
    let cfg = Config::new();
    let opts = TrainerOptions {
        n_clients: 2,
        rounds: 1,
        eval_every: 1,
        dataset_size: 400,
        test_size: 256,
        ..Default::default()
    };
    let run = train(rt, manifest, &cfg, &opts)?;
    let r = &run.rounds[0];
    println!(
        "round 0: loss {:.4}, train acc {:.3}, test acc {:.3}",
        r.loss,
        r.train_acc,
        r.test_acc.unwrap_or(f64::NAN)
    );
    println!(
        "simulated round: {:.3}s total (uplink phase {:.3}s, server \
         fp+bp {:.3}s, gradient return {:.3}s)",
        r.sim_latency,
        r.stages.uplink_phase,
        r.stages.server_fp + r.stages.server_bp,
        r.stages.broadcast + r.stages.downlink_phase
    );

    // 3. Resource management on a simulated wireless deployment.
    let profile = resnet18::profile();
    let mut rng = Rng::new(1);
    let dep = Deployment::generate(&cfg.net, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cfg.net,
        profile: &profile,
        dep: &dep,
        ch: &ch,
        batch: cfg.train.batch,
        phi: 0.5,
    };
    let res = bcd::solve(&prob, bcd::BcdOptions::default())?;
    let s = prob.stage_latencies(&res.decision);
    let cut = res.decision.uniform_cut()?;
    println!(
        "\noptimized deployment (C=5, ResNet-18 profile): cut layer {} \
         ({}), per-round latency {:.3}s",
        cut,
        profile.layers[cut - 1].name,
        res.objective
    );
    println!(
        "  uplink phase {:.3}s | server fp {:.3}s | server bp {:.3}s | \
         broadcast {:.3}s | downlink phase {:.3}s",
        s.uplink_phase_max(),
        s.server_fp,
        s.server_bp,
        s.broadcast,
        s.downlink_phase_max()
    );
    println!("\nquickstart OK");
    Ok(())
}
