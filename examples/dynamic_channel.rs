//! Dynamic-channel walkthrough: expand one multi-round scenario (block
//! fading + LoS flips + compute jitter) and compare re-optimization
//! policies over the *same* realizations — when is "optimize once"
//! (paper §VII, Fig. 13) still good enough, and what does adapting buy?
//!
//! Runs entirely on the analytical §V model — no artifacts needed.
//!
//! Usage: cargo run --release --example dynamic_channel [seed] [rounds]

use epsl::config::NetworkConfig;
use epsl::optim::bcd::BcdOptions;
use epsl::profile::resnet18;
use epsl::scenario::{
    pair_latencies, run_policy, ComputeJitterSpec, LosFlipSpec, ReoptPolicy,
    RunOptions, Scenario, ScenarioSpec,
};
use epsl::timeline::Mode;
use epsl::util::par;
use epsl::util::table::{bar_chart, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0x13);
    let rounds: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);

    let net = NetworkConfig::default();
    let profile = resnet18::profile_static();
    let spec = ScenarioSpec {
        rounds,
        redraw_period: Some(2),
        los_flip: Some(LosFlipSpec { flip_prob: 0.2 }),
        compute_jitter: Some(ComputeJitterSpec { amplitude: 0.1 }),
        churn: None,
    };
    let sc = Scenario::generate(&net, &spec, seed)?;
    println!(
        "scenario (seed {seed}): {} rounds, fading redraw every 2 rounds, \
         LoS Markov flips (p=0.2), ±10% compute jitter\n",
        sc.n_rounds()
    );

    let policies = [
        ReoptPolicy::Never,
        ReoptPolicy::EveryK(4),
        ReoptPolicy::OnRegression(1.2),
        ReoptPolicy::EveryK(1), // oracle
    ];
    let mut t = Table::new("policy comparison (same realizations)").header(
        &["policy", "mean latency (s)", "worst round (s)", "solves"],
    );
    let mut items = Vec::new();
    let mut outcomes = Vec::new();
    for policy in policies {
        let out = run_policy(
            &sc,
            profile,
            &RunOptions {
                policy,
                bcd: BcdOptions { max_iters: 6, tol: 1e-4 },
                batch: 64,
                phi: 0.5,
                threads: par::max_threads(),
                timeline_mode: Mode::Barrier,
            },
        );
        let worst = out
            .rounds
            .iter()
            .filter_map(|r| r.latency)
            .fold(0.0, f64::max);
        t.row(&[
            policy.name(),
            format!("{:.3}", out.mean_latency()),
            format!("{worst:.3}"),
            out.n_solves.to_string(),
        ]);
        items.push((policy.name(), out.mean_latency()));
        outcomes.push(out);
    }
    println!("{}", t.render());
    println!(
        "{}",
        bar_chart("mean per-round latency by policy", &items, "s")
    );

    // Fixed-vs-oracle, paired per realization (the Fig. 13 robustness
    // number for this scenario).
    let fixed = &outcomes[0];
    let oracle = &outcomes[policies.len() - 1];
    let p = pair_latencies(&fixed.latencies(), &oracle.latencies());
    println!(
        "fixed/oracle over {} paired rounds: {:.3} (1.0 = adapting every \
         round buys nothing)",
        p.n_pairs,
        p.ratio()
    );
    if p.n_dropped > 0 {
        println!("({} rounds dropped from both means)", p.n_dropped);
    }

    // Timeline modes: the same fixed decision, with the gradient/compute
    // phases overlapped per client instead of barrier-synchronized.
    let pipelined = run_policy(
        &sc,
        profile,
        &RunOptions {
            policy: ReoptPolicy::Never,
            bcd: BcdOptions { max_iters: 6, tol: 1e-4 },
            batch: 64,
            phi: 0.5,
            threads: par::max_threads(),
            timeline_mode: Mode::Pipelined,
        },
    );
    println!(
        "\ntimeline modes (fixed decision): barrier {:.3}s/round vs \
         pipelined {:.3}s/round ({:.1}% saved by overlap)",
        fixed.mean_latency(),
        pipelined.mean_latency(),
        100.0 * (1.0 - pipelined.mean_latency() / fixed.mean_latency())
    );
    Ok(())
}
