//! Framework shoot-out: train vanilla SL / SFL / PSL / EPSL(0.5) / EPSL(1)
//! side by side on the same synthetic corpus and report accuracy, rounds
//! and simulated latency to a target — the paper's Fig. 4 in one command.
//!
//! Usage: cargo run --release --example framework_compare [rounds] [target]

use epsl::config::Config;
use epsl::coordinator::{train, TrainerOptions};
use epsl::latency::frameworks::Framework;
use epsl::runtime::{select_backend, BackendChoice};
use epsl::util::table::{LinePlot, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rounds: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let target: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.6);

    // PJRT artifacts when built, the pure-Rust native backend otherwise.
    let sel = select_backend("artifacts", BackendChoice::Auto)?;
    let (rt, manifest) = (sel.backend.as_ref(), &sel.manifest);
    println!("backend: {}", sel.describe());
    let cfg = Config::new();

    let frameworks = [
        ("vanilla SL", Framework::VanillaSl),
        ("SFL", Framework::Sfl),
        ("PSL", Framework::Psl),
        ("EPSL(0.5)", Framework::Epsl { phi: 0.5 }),
        ("EPSL(1.0)", Framework::Epsl { phi: 1.0 }),
        ("EPSL-PT", Framework::EpslPt { early: true }),
    ];
    let mut t = Table::new(format!(
        "framework comparison — {rounds} rounds, target {target}"
    ).as_str())
    .header(&[
        "framework",
        "final acc",
        "rounds→target",
        "per-round lat (s)",
        "latency→target (s)",
    ]);
    let mut plot =
        LinePlot::new("test accuracy vs round", "round", "accuracy");
    for (name, fw) in frameworks {
        let opts = TrainerOptions {
            family: "mnist".into(),
            framework: fw,
            n_clients: 5,
            rounds,
            eval_every: 10,
            dataset_size: 2000,
            test_size: 512,
            eta_c: 0.1,
            eta_s: 0.1,
            pt_switch: rounds / 3,
            ..Default::default()
        };
        let run = train(rt, manifest, &cfg, &opts)?;
        plot.series(name, &run.accuracy_curve());
        let r2t = run.rounds_to_accuracy(target);
        let l2t = run.latency_to_accuracy(target);
        t.row(&[
            name.to_string(),
            format!("{:.3}", run.converged_accuracy(3)),
            r2t.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
            format!("{:.3}", run.rounds[0].sim_latency),
            l2t.map(|l| format!("{l:.1}")).unwrap_or_else(|| "-".into()),
        ]);
        println!(
            "{name:<12} done: acc {:.3}",
            run.converged_accuracy(3)
        );
    }
    println!("\n{}", plot.render());
    println!("{}", t.render());
    Ok(())
}
