//! Resource-management walkthrough: run the full BCD optimizer and all four
//! baselines on one heterogeneous deployment and show where the latency
//! goes — the paper's §VII-C story (cut-layer selection dominates).
//!
//! Usage: cargo run --release --example resource_opt [seed] [clients]

use epsl::channel::{ChannelRealization, Deployment};
use epsl::config::NetworkConfig;
use epsl::optim::baselines::{self, Scheme};
use epsl::optim::{bcd, Problem};
use epsl::profile::resnet18;
use epsl::util::rng::Rng;
use epsl::util::table::{bar_chart, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(11);
    let clients: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5);

    let mut net = NetworkConfig::default();
    net.n_clients = clients;
    let profile = resnet18::profile();
    let mut rng = Rng::new(seed);
    let dep = Deployment::generate(&net, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &net,
        profile: &profile,
        dep: &dep,
        ch: &ch,
        batch: 64,
        phi: 0.5,
    };

    println!("deployment (seed {seed}):");
    let mut t = Table::new("clients")
        .header(&["client", "f (GHz)", "distance (m)", "LoS"]);
    for (i, c) in dep.clients.iter().enumerate() {
        t.row(&[
            i.to_string(),
            format!("{:.2}", c.f_client / 1e9),
            format!("{:.0}", c.distance_m),
            c.los.to_string(),
        ]);
    }
    println!("{}", t.render());

    let mut items = Vec::new();
    for scheme in Scheme::all() {
        let mut srng = Rng::new(999);
        let d = baselines::solve(&prob, scheme, &mut srng)?;
        let obj = prob.objective(&d);
        println!(
            "{:<38} cut={:<2} latency={:.3}s",
            scheme.name(),
            d.cut,
            obj
        );
        items.push((scheme.name().to_string(), obj));
    }
    println!();
    println!("{}", bar_chart("per-round latency by scheme", &items, "s"));

    // BCD trajectory detail.
    let res = bcd::solve(&prob, bcd::BcdOptions::default())?;
    println!(
        "BCD trajectory ({} iterations): {}",
        res.iterations,
        res.trajectory
            .iter()
            .map(|v| format!("{v:.3}"))
            .collect::<Vec<_>>()
            .join(" → ")
    );
    Ok(())
}
