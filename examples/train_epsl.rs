//! End-to-end validation driver (EXPERIMENTS.md §E2E): train SplitNet with
//! EPSL across simulated edge clients for a few hundred rounds on the
//! synthetic corpus, logging the loss/accuracy curve and the simulated
//! per-round latency — proving all three layers compose (Pallas kernel →
//! JAX AOT graphs → rust coordinator/PJRT).
//!
//! Usage: cargo run --release --example train_epsl [rounds] [phi] [clients]

use epsl::config::Config;
use epsl::coordinator::{train, TrainerOptions};
use epsl::latency::frameworks::Framework;
use epsl::runtime::{select_backend, Backend, BackendChoice};
use epsl::util::table::LinePlot;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rounds: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let phi: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let clients: usize =
        args.get(3).and_then(|s| s.parse().ok()).unwrap_or(5);
    let eta: f32 = args.get(4).and_then(|s| s.parse().ok()).unwrap_or(0.08);

    let sel = select_backend("artifacts", BackendChoice::Auto)?;
    let (rt, manifest) = (sel.backend.as_ref(), &sel.manifest);
    let cfg = Config::new();
    println!(
        "EPSL e2e: {} rounds, phi={}, C={}, platform={}",
        rounds,
        phi,
        clients,
        rt.platform()
    );

    let opts = TrainerOptions {
        family: "mnist".into(),
        framework: Framework::Epsl { phi },
        n_clients: clients,
        cut: 2,
        rounds,
        eval_every: 10,
        dataset_size: 2000,
        test_size: 512,
        optimize_resources: true,
        eta_c: eta,
        eta_s: eta,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let run = train(rt, manifest, &cfg, &opts)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nround   loss    train_acc  test_acc  sim_latency(s)");
    for r in &run.rounds {
        if r.round % 10 == 9 || r.round == 0 {
            println!(
                "{:>5}  {:.4}   {:.3}      {}      {:.3}",
                r.round,
                r.loss,
                r.train_acc,
                match r.test_acc {
                    None => "  -  ".to_string(),
                    Some(a) => format!("{a:.3}"),
                },
                r.sim_latency
            );
        }
    }
    let mut plot = LinePlot::new("EPSL training", "round", "value");
    plot.series("loss", &run.loss_curve());
    plot.series("test_acc", &run.accuracy_curve());
    println!("\n{}", plot.render());
    println!("final test accuracy : {:.3}", run.converged_accuracy(3));
    println!(
        "total simulated latency: {:.1} s over {} rounds",
        run.total_latency(),
        run.rounds.len()
    );
    println!(
        "wall-clock: {wall:.1} s  ({:.0} ms/round)",
        1e3 * wall / rounds as f64
    );
    println!("{}", rt.stats_summary());
    Ok(())
}
